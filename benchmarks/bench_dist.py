"""Beyond-paper: multi-chip block-panel Cholesky (core.distributed).

Runs the shard_map solver on 8 forced host devices, checks exactness vs
the single-device tree, and times both collective schedules (gather-panel
vs diag-broadcast) — the §Perf hillclimb lever for the solver.
Requires a session started with --xla_force_host_platform_device_count=8;
skips otherwise (benchmarks/run.py launches it correctly).
"""
from __future__ import annotations

import functools

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from benchmarks.util import emit, spd_matrix, timeit
from repro.core import PrecisionConfig, cholesky
from repro.core.distributed import dist_cholesky
from repro.launch.mesh import make_mesh


def run(sizes=(1024, 2048)):
    if jax.device_count() < 8:
        emit("dist_cholesky", 0.0, "skipped=needs_8_devices")
        return
    mesh = make_mesh((8,), ("model",))
    cfg = PrecisionConfig(levels=("f32",), leaf=128)
    for n in sizes:
        a = spd_matrix(n)
        a_sh = jax.device_put(a, NamedSharding(mesh, P("model", None)))
        with mesh:
            for tag, bd in (("bcast_diag", True), ("gather_panel", False)):
                fn = jax.jit(functools.partial(
                    dist_cholesky, mesh=mesh, cfg=cfg,
                    broadcast_diag_only=bd))
                t = timeit(fn, a_sh, warmup=1, iters=3)
                emit(f"dist_potrf_{tag}_n{n}_p8", t, "devices=8")
            l = np.asarray(fn(a_sh), np.float64)
        ref = np.asarray(jax.jit(functools.partial(cholesky, cfg=cfg))(a),
                         np.float64)
        rel = np.abs(l - ref).max() / np.abs(ref).max()
        emit(f"dist_potrf_agreement_n{n}", 0.0, f"rel={rel:.2e}")


if __name__ == "__main__":
    from benchmarks.util import smoke_mode
    run(sizes=(1024,) if smoke_mode() else (1024, 2048))  # 8 shards x leaf 128
