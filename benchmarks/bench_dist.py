"""Beyond-paper: multi-chip precision-planned Cholesky (core.distributed).

Races the two levers the distributed engine added on a forced
4-host-device CPU mesh:

* LOCAL ENGINE — the plan-driven blocked local path (``engine="blocked"``,
  the default) vs the legacy recursive tree local path (``engine="tree"``),
  both on full-precision gathers so only local compute differs.
* COLLECTIVES — plan-compressed gathers (``compress_comm=True``, the
  16-bit/int8 wire format chosen per panel by the sharded plan) vs full
  f32 gathers, both on the blocked local engine.

Writes ``BENCH_dist.json`` at the repo root for CI's dist gate
(compressed collectives must not be slower than f32 gathers at
n >= 2048) and emits the usual ``name,us_per_call,derived`` CSV rows.
Requires a session started with --xla_force_host_platform_device_count=4
(benchmarks/run.py and CI's dist-smoke job launch it correctly); skips
otherwise.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from benchmarks.util import emit, spd_matrix, timeit
from repro.core import PrecisionConfig, cholesky
from repro.core.distributed import dist_cholesky
from repro.launch.mesh import make_mesh

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
NSHARDS = 4


def run(sizes=(1024, 2048), json_path=None):
    if jax.device_count() < NSHARDS:
        emit("dist_cholesky", 0.0, f"skipped=needs_{NSHARDS}_devices")
        # still write the artifact: CI's gate asserts rows is non-empty,
        # so a silently-skipped bench fails the gate with a clear
        # message instead of passing on a stale file (or crashing on a
        # missing one)
        path = json_path or os.path.join(_ROOT, "BENCH_dist.json")
        with open(path, "w") as f:
            json.dump({"bench": "dist_cholesky", "nshards": NSHARDS,
                       "skipped": f"needs_{NSHARDS}_devices", "rows": []},
                      f, indent=1)
        return []
    mesh = make_mesh((NSHARDS,), ("model",))
    # bf16_f32 at leaf 128: multiple tile rows per shard (the fused
    # local panel path) and genuinely compressible early panels
    cfg = PrecisionConfig(levels=("bf16", "f32"), leaf=128)
    rows = []
    for n in sizes:
        a = spd_matrix(n)
        a_sh = jax.device_put(a, NamedSharding(mesh, P("model", None)))
        row = {"n": n, "ladder": "bf16_f32", "leaf": cfg.leaf,
               "nshards": NSHARDS}
        with mesh:
            # local-engine race (full gathers: same comm both sides)
            for eng in ("tree", "blocked"):
                cfg_e = dataclasses.replace(cfg, engine=eng)
                fn = jax.jit(functools.partial(
                    dist_cholesky, mesh=mesh, cfg=cfg_e,
                    compress_comm=False))
                t = timeit(fn, a_sh, warmup=2, iters=7)
                row[f"us_local_{eng}"] = round(t, 1)
                emit(f"dist_potrf_local_{eng}_n{n}_p{NSHARDS}", t,
                     f"devices={NSHARDS}")
            # collective race (blocked engine both sides)
            for tag, cc in (("f32_gather", False), ("compressed", True)):
                fn = jax.jit(functools.partial(
                    dist_cholesky, mesh=mesh, cfg=cfg, compress_comm=cc))
                t = timeit(fn, a_sh, warmup=2, iters=7)
                row[f"us_comm_{tag}"] = round(t, 1)
                emit(f"dist_potrf_comm_{tag}_n{n}_p{NSHARDS}", t,
                     f"devices={NSHARDS}")
            l = np.asarray(fn(a_sh), np.float64)
        row["speedup_blocked_vs_tree"] = round(
            row["us_local_tree"] / row["us_local_blocked"], 3)
        row["speedup_compressed_vs_f32"] = round(
            row["us_comm_f32_gather"] / row["us_comm_compressed"], 3)
        # agreement with the single-device planned engine
        ref = np.asarray(jax.jit(functools.partial(cholesky, cfg=cfg))(a),
                         np.float64)
        rel = np.abs(l - ref).max() / np.abs(ref).max()
        row["rel_vs_single_device"] = float(f"{rel:.3e}")
        emit(f"dist_potrf_speedups_n{n}", row["us_comm_compressed"],
             f"blocked_vs_tree={row['speedup_blocked_vs_tree']};"
             f"compressed_vs_f32={row['speedup_compressed_vs_f32']};"
             f"rel={rel:.2e}")
        rows.append(row)
    path = json_path or os.path.join(_ROOT, "BENCH_dist.json")
    with open(path, "w") as f:
        json.dump({"bench": "dist_cholesky", "nshards": NSHARDS,
                   "rows": rows}, f, indent=1)
    return rows


if __name__ == "__main__":
    import argparse
    import sys

    from benchmarks.util import ROWS, smoke_mode

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes (CI dist-smoke job)")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write CSV rows as a JSON artifact")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(sizes=(1024, 2048) if (args.smoke or smoke_mode())
        else (1024, 2048, 4096))
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"smoke": bool(args.smoke), "rows": list(ROWS)},
                      f, indent=1)
        print(f"# wrote {len(ROWS)} rows to {args.out}", file=sys.stderr)
