"""Beyond-paper: multi-chip precision-planned Cholesky (core.distributed).

Races the two levers the distributed engine added on a forced
4-host-device CPU mesh:

* LOCAL ENGINE — the plan-driven blocked local path (``engine="blocked"``,
  the default) vs the legacy recursive tree local path (``engine="tree"``),
  both on full-precision gathers so only local compute differs.
* COLLECTIVES — plan-compressed gathers (``compress_comm=True``, the
  16-bit/int8 wire format chosen per panel by the sharded plan) vs full
  f32 gathers, both on the blocked local engine.
* TUNED SELECTION — what the committed tuning database (repro.tune)
  picks for each size: the tuned engine, its provenance, and whether
  ``engine="auto"`` traces to the identical computation. This is the
  measured resolution of the n=1024 tree-vs-blocked flip: below the
  DB crossover the tuned engine must be the tree
  (``speedup_tuned_vs_tree == 1.0`` there by construction).

Writes ``BENCH_dist.json`` at the repo root for CI's dist gate
(``tools/perf_gate.py dist``: compressed collectives must not be slower
than f32 gathers at n >= 2048, and the tuned engine must win its side
of the crossover) and emits the usual ``name,us_per_call,derived`` CSV
rows.
Requires a session started with --xla_force_host_platform_device_count=4
(benchmarks/run.py and CI's dist-smoke job launch it correctly); skips
otherwise.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from benchmarks.util import emit, spd_matrix, timeit
from repro import tune
from repro.tune.search import race
from repro.core import PrecisionConfig, cholesky
from repro.core.distributed import dist_cholesky
from repro.launch.mesh import make_mesh

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
NSHARDS = 4


def run(sizes=(1024, 2048), json_path=None):
    if jax.device_count() < NSHARDS:
        emit("dist_cholesky", 0.0, f"skipped=needs_{NSHARDS}_devices")
        # still write the artifact: CI's gate asserts rows is non-empty,
        # so a silently-skipped bench fails the gate with a clear
        # message instead of passing on a stale file (or crashing on a
        # missing one)
        path = json_path or os.path.join(_ROOT, "BENCH_dist.json")
        with open(path, "w") as f:
            json.dump({"bench": "dist_cholesky", "nshards": NSHARDS,
                       "skipped": f"needs_{NSHARDS}_devices", "rows": []},
                      f, indent=1)
        return []
    mesh = make_mesh((NSHARDS,), ("model",))
    # bf16_f32 at leaf 128: multiple tile rows per shard (the fused
    # local panel path) and genuinely compressible early panels
    cfg = PrecisionConfig(levels=("bf16", "f32"), leaf=128)
    rows = []
    for n in sizes:
        a = spd_matrix(n)
        a_sh = jax.device_put(a, NamedSharding(mesh, P("model", None)))
        row = {"n": n, "ladder": "bf16_f32", "leaf": cfg.leaf,
               "nshards": NSHARDS}
        with mesh:
            # one interleaved race over all four candidates (tune.search
            # .race: fresh executable per round, per-candidate min) —
            # sequential one-shot timing here let a sticky slow
            # compile/allocation layout tax a single candidate ~1.4x and
            # fake an engine flip. Local engines run on full-precision
            # gathers (same comm both sides); the collective pair both
            # run the blocked engine.
            def make(cfg_e, cc):
                return lambda: (
                    jax.jit(functools.partial(dist_cholesky, mesh=mesh,
                                              cfg=cfg_e, compress_comm=cc)),
                    (jax.device_put(a, NamedSharding(mesh,
                                                     P("model", None))),))
            cands = {
                "us_local_tree":
                    make(dataclasses.replace(cfg, engine="tree"), False),
                "us_local_blocked":
                    make(dataclasses.replace(cfg, engine="blocked"), False),
                "us_comm_f32_gather": make(cfg, False),
                "us_comm_compressed": make(cfg, True),
            }
            timer = functools.partial(timeit, warmup=2, iters=7)
            for key, t in race(timer, cands).items():
                row[key] = round(t, 1)
                emit(f"dist_potrf_{key[3:]}_n{n}_p{NSHARDS}", t,
                     f"devices={NSHARDS}")
            fn_c, args_c = make(cfg, True)()
            l = np.asarray(fn_c(*args_c), np.float64)
            # tuned selection: what the committed DB picks for this key,
            # and whether engine="auto" traces to that exact computation
            dec = tune.decide(n, "bf16_f32", NSHARDS)
            row["tuned_engine"] = dec.engine
            row["tuned_source"] = dec.source
            db = tune.get_default_db()
            cx = db.crossover("bf16_f32", NSHARDS) if db else None
            row["tuned_crossover_n"] = cx["n"] if cx else None
            row["us_local_tuned"] = row[f"us_local_{dec.engine}"]
            eqns = {}
            for tag, eng in (("auto", "auto"), ("tuned", dec.engine)):
                cfg_e = dataclasses.replace(cfg, engine=eng)
                jaxpr = jax.make_jaxpr(functools.partial(
                    dist_cholesky, mesh=mesh, cfg=cfg_e,
                    compress_comm=False))(a_sh)
                eqns[tag] = len(jaxpr.eqns)
            row["auto_matches_tuned"] = eqns["auto"] == eqns["tuned"]
        row["speedup_blocked_vs_tree"] = round(
            row["us_local_tree"] / row["us_local_blocked"], 3)
        row["speedup_tuned_vs_tree"] = round(
            row["us_local_tree"] / row["us_local_tuned"], 3)
        row["speedup_compressed_vs_f32"] = round(
            row["us_comm_f32_gather"] / row["us_comm_compressed"], 3)
        # agreement with the single-device planned engine
        ref = np.asarray(jax.jit(functools.partial(cholesky, cfg=cfg))(a),
                         np.float64)
        rel = np.abs(l - ref).max() / np.abs(ref).max()
        row["rel_vs_single_device"] = float(f"{rel:.3e}")
        emit(f"dist_potrf_speedups_n{n}", row["us_comm_compressed"],
             f"blocked_vs_tree={row['speedup_blocked_vs_tree']};"
             f"compressed_vs_f32={row['speedup_compressed_vs_f32']};"
             f"rel={rel:.2e}")
        emit(f"dist_potrf_tuned_n{n}", row["us_local_tuned"],
             f"engine={row['tuned_engine']};source={row['tuned_source']};"
             f"crossover_n={row['tuned_crossover_n']};"
             f"auto_matches={row['auto_matches_tuned']}")
        rows.append(row)
    path = json_path or os.path.join(_ROOT, "BENCH_dist.json")
    with open(path, "w") as f:
        json.dump({"bench": "dist_cholesky", "nshards": NSHARDS,
                   "rows": rows}, f, indent=1)
    return rows


if __name__ == "__main__":
    import argparse
    import sys

    from benchmarks.util import ROWS, smoke_mode

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes (CI dist-smoke job)")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write CSV rows as a JSON artifact")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(sizes=(1024, 2048) if (args.smoke or smoke_mode())
        else (1024, 2048, 4096))
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"smoke": bool(args.smoke), "rows": list(ROWS)},
                      f, indent=1)
        print(f"# wrote {len(ROWS)} rows to {args.out}", file=sys.stderr)
