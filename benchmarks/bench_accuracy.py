"""Fig. 8 — Cholesky accuracy ladder (the claim this container can verify
EXACTLY: CPU has f64).

For each precision config, factor the paper's SPD test matrix and report
-log10(relative error) against the f64 factor ("digits"). The paper's
ordering must reproduce:
  f64 > [f32,f32,f32,f64] > f32 > [f16,f32] > [f16..f32] > pure f16
with the mixed ladders ~2 orders of magnitude more accurate than pure
f16 while exposing the same low-precision GEMM fraction.

Also reproduces §III-D: with quantization ON a badly-scaled SPD system
(entries ~1e8) factors fine in f16 levels; with quantization OFF it
overflows to inf/nan.
"""
from __future__ import annotations

import numpy as np

import jax

from benchmarks.util import emit, spd_matrix, timeit
from repro.core import PrecisionConfig, cholesky

LADDER = [
    ("pure_f64", ("f64",)),
    ("f32x3_f64", ("f32", "f32", "f32", "f64")),
    ("pure_f32", ("f32",)),
    ("f16_f32", ("f16", "f32")),
    ("f16x3_f32", ("f16", "f16", "f16", "f32")),
    ("f16x5_f32", ("f16",) * 5 + ("f32",)),
    ("pure_f16", ("f16",)),
]


def digits(a64, cfg):
    import functools
    fn = jax.jit(functools.partial(cholesky, cfg=cfg))
    container = np.float64 if cfg.high_name == "f64" else np.float32
    t = timeit(fn, a64.astype(container))
    l = np.asarray(fn(a64.astype(container)), np.float64)
    ref = np.linalg.cholesky(a64)
    err = np.linalg.norm(l - ref) / np.linalg.norm(ref)
    return -np.log10(max(err, 1e-17)), t


def run(sizes=(1024, 2048)):
    assert jax.config.jax_enable_x64, "bench_accuracy needs x64"
    for n in sizes:
        a64 = spd_matrix(n, dtype=np.float64)
        errs = {}
        for name, levels in LADDER:
            cfg = PrecisionConfig(levels=levels, leaf=128)
            d, t = digits(a64, cfg)
            errs[name] = d
            emit(f"accuracy_{name}_n{n}", t, f"digits={d:.2f}")
        gain = errs["f16x3_f32"] - errs["pure_f16"]
        emit(f"accuracy_mixed_vs_puref16_n{n}", 0.0,
             f"orders_of_magnitude={gain:.2f};paper_claims=~2")

        # §III-D overflow protection
        big = a64 * 1e6
        for q in (True, False):
            cfg = PrecisionConfig(levels=("f16", "f32"), leaf=128,
                                  quantize=q)
            import functools
            fn = jax.jit(functools.partial(cholesky, cfg=cfg))
            l = np.asarray(fn(big.astype(np.float32)), np.float64)
            finite = bool(np.isfinite(l).all())
            emit(f"quantize_{'on' if q else 'off'}_scale1e6_n{n}", 0.0,
                 f"finite={finite};expected={'True' if q else 'False'}")


if __name__ == "__main__":
    from benchmarks.util import smoke_mode
    run(sizes=(256,) if smoke_mode() else (1024, 2048))
