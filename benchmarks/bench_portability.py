"""Figs. 9 + 11 — Portability across backends.

The paper's portability story (one Julia algorithm, cuBLAS/rocBLAS leaf
dispatch) maps to ops.py's impl dispatch: the same tree algorithm runs
with 'jnp' leaves (XLA:CPU/GPU path) and 'interpret' leaves (the Pallas
TPU kernels executed by the interpreter). We verify both backends agree
to f32 tolerance and report their timings. (AMD MI300X numbers are not
reproducible in this container; the dispatch mechanism is the claim.)
"""
from __future__ import annotations

import functools

import jax
import numpy as np

from benchmarks.util import emit, spd_matrix, timeit
from repro.core import PrecisionConfig, cholesky


def run(sizes=(256, 512)):
    for n in sizes:
        a = spd_matrix(n)
        outs = {}
        for impl in ("jnp", "interpret"):
            cfg = PrecisionConfig(levels=("f16", "f32"), leaf=128,
                                  kernel_impl=impl)
            fn = jax.jit(functools.partial(cholesky, cfg=cfg))
            t = timeit(fn, a, warmup=1, iters=2)
            outs[impl] = np.asarray(fn(a), np.float64)
            emit(f"portability_{impl}_n{n}", t, f"backend={impl}")
        dev = np.abs(outs["jnp"] - outs["interpret"]).max()
        rel = dev / np.abs(outs["jnp"]).max()
        emit(f"portability_agreement_n{n}", 0.0,
             f"max_rel_dev={rel:.2e};agree={rel < 1e-2}")


if __name__ == "__main__":
    run()
