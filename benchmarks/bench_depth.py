"""Fig. 10 — Speedup scaling with matrix size / recursion depth.

The paper's mechanism: larger matrices admit deeper recursion, which puts
a larger fraction of FLOPs into low-precision off-diagonal GEMMs. That
fraction (and the resulting modeled speedup) is computed exactly from the
structural census — this is the size-scaling claim reproduced without GPU
hardware. CPU wall-times for the same sweep show the recursion overhead
staying sub-linear.
"""
from __future__ import annotations

import functools

import jax

from benchmarks.util import emit, model_time_s, spd_matrix, timeit
from repro.core import PrecisionConfig, census_potrf, cholesky


def run(sizes=(256, 512, 1024, 2048, 4096, 8192, 16384, 32768, 65536)):
    for n in sizes:
        cfg = PrecisionConfig(levels=("f16",) * 5 + ("f32",), leaf=256)
        cen = census_potrf(n, cfg)
        t32 = model_time_s(census_potrf(n, PrecisionConfig(
            levels=("f32",), leaf=256)))
        tm = model_time_s(cen)
        depth = cfg.depth(n)
        if n <= 2048:  # wall-clock on CPU for the small end
            fn = jax.jit(functools.partial(cholesky, cfg=cfg))
            t = timeit(fn, spd_matrix(n))
        else:
            t = 0.0
        emit(f"depth_scaling_n{n}", t,
             f"depth={depth};lowp_frac={cen.lowp_fraction():.4f};"
             f"gemm_frac={cen.gemm_fraction:.4f};"
             f"model_v5e_speedup={t32 / tm:.2f}")


if __name__ == "__main__":
    run()
