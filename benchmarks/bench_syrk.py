"""Fig. 4 — Recursive SYRK speedup.

Measured: CPU wall-time of tree-SYRK (recursion overhead is real) vs the
XLA-fused baseline (C - A A^T masked), per precision config and size.
Derived: v5e-modeled speedup over the uniform-f32 baseline from the
structural census (compute + HBM terms). The paper's 14x/27x/149x come
from the H200's fp64:fp16 = 1:30 MXU ratio; the v5e analogue is
f32:bf16 = 1:2 compute + 2x bandwidth — the *structure* (GEMM fraction,
deeper-recursion -> more low-precision FLOPs) is the reproduced claim.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import emit, model_time_s, spd_matrix, timeit
from repro.core import PrecisionConfig, census_syrk, tree_syrk

CONFIGS = {
    "f32": PrecisionConfig(levels=("f32",), leaf=128),
    "bf16_f32": PrecisionConfig(levels=("bf16", "f32"), leaf=128),
    "f16_f32": PrecisionConfig(levels=("f16", "f32"), leaf=128),
    "f16x3_f32": PrecisionConfig(levels=("f16",) * 3 + ("f32",), leaf=128),
    "pure_f16": PrecisionConfig(levels=("f16",), leaf=128),
}


def baseline(c, a):
    upd = c - jnp.dot(a, a.T)
    return jnp.where(jnp.tril(jnp.ones_like(c, dtype=bool)), upd, c)


def run(sizes=(512, 1024, 2048)):
    for n in sizes:
        k = n // 2
        rng = np.random.default_rng(0)
        c = spd_matrix(n)
        a = rng.standard_normal((n, k)).astype(np.float32)

        base = jax.jit(baseline)
        t_base = timeit(base, c, a)
        emit(f"syrk_baseline_xla_f32_n{n}", t_base, "speedup=1.00")

        cen32 = census_syrk(n, k, CONFIGS["f32"])
        t32_model = model_time_s(cen32)
        for name, cfg in CONFIGS.items():
            fn = jax.jit(functools.partial(
                tree_syrk, alpha=-1.0, beta=1.0, cfg=cfg))
            t = timeit(fn, c, a)
            cen = census_syrk(n, k, cfg)
            model_speedup = t32_model / model_time_s(cen)
            emit(f"syrk_tree_{name}_n{n}", t,
                 f"model_v5e_speedup={model_speedup:.2f};"
                 f"gemm_frac={cen.gemm_fraction:.3f};"
                 f"lowp_frac={cen.lowp_fraction():.3f};"
                 f"cpu_speedup={t_base / t:.2f}")


if __name__ == "__main__":
    run()
