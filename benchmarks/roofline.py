"""Roofline analysis (assignment §ROOFLINE ANALYSIS).

Reads experiments/dryrun/<cell>.json (produced by repro.launch.dryrun)
and derives, per (arch x shape x mesh):

  compute term    = HLO_FLOPs / (chips * 197e12)         [s]
  memory term     = HLO_bytes / (chips * 819e9)          [s]
  collective term = collective_bytes / (chips * 50e9)    [s]

HLO_FLOPs/bytes come from the while-trip-corrected HLO census (the raw
cost_analysis numbers are also recorded; they undercount scan bodies —
see tests/test_roofline.py). The census is per device, so terms divide
by 1, not chips; we report both per-device seconds and the global
MODEL_FLOPS ratio.

MODEL_FLOPS: 6*N*D for dense training (N = params, D = tokens), with the
MoE active-parameter correction; for inference: 2*N*D (fwd only).
"""
from __future__ import annotations

import glob
import json
import os

PEAK = 197e12        # bf16 FLOP/s per chip
HBM = 819e9          # B/s per chip
ICI = 50e9           # B/s per link

_ACTIVE_FRACTION = {  # active params / total params (MoE)
    "deepseek-v2-lite-16b": 0.165,   # ~2.6B active^ /15.7B
    "deepseek-v3-671b": 0.055,       # ~37B active /671B
}


def model_flops(rec) -> float:
    n = rec["n_params"]
    arch = rec["arch"]
    n_active = n * _ACTIVE_FRACTION.get(arch, 1.0)
    shape = rec["shape"]
    if shape.startswith("train"):
        tokens = 4096 * 256
        return 6.0 * n_active * tokens
    if shape.startswith("prefill"):
        tokens = 32768 * 32
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    batch = 1 if shape.startswith("long") else 128
    return 2.0 * n_active * batch


def analyze(rec) -> dict:
    chips = rec["n_devices"]
    flops_dev = rec["census"]["flops"]
    bytes_dev = rec["census"]["hbm_bytes"]
    coll_dev = sum(v["bytes"] for v in rec["collectives"].values())
    t_c = flops_dev / PEAK
    t_m = bytes_dev / HBM
    t_x = coll_dev / ICI
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    mf = model_flops(rec)
    hlo_global = flops_dev * chips
    out = {
        "arch": rec["arch"], "shape": rec["shape"],
        "mesh": "2x16x16" if rec["multi_pod"] else "16x16",
        "chips": chips,
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom,
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "roofline_fraction": t_c / max(t_c + t_m + t_x, 1e-30),
        "gib_per_dev": rec["per_device_bytes"] / 2**30,
        "step_time_lb_s": max(t_c, t_m, t_x),
        "kfac": rec.get("kfac", False),
    }
    # effective MFU proxy: useful model flops / (chips*peak*step_time)
    out["mfu_model"] = mf / (chips * PEAK * max(out["step_time_lb_s"],
                                                1e-30))
    return out


def load(dirpath="experiments/dryrun"):
    recs = []
    for f in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def run_csv(dirpath="experiments/dryrun"):
    for rec in load(dirpath):
        a = analyze(rec)
        tag = ("kfac-" if a["kfac"] else "") + \
            f"roofline_{a['arch']}_{a['shape']}_{a['mesh']}"
        print(f"{tag},0.0,"
              f"compute_s={a['compute_s']:.4f};memory_s={a['memory_s']:.4f};"
              f"collective_s={a['collective_s']:.4f};dom={a['dominant']};"
              f"useful={a['useful_ratio']:.3f};mfu={a['mfu_model']:.3f};"
              f"GiB/dev={a['gib_per_dev']:.2f}")


def markdown_table(dirpath="experiments/dryrun"):
    rows = [analyze(r) for r in load(dirpath)]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"], r["kfac"]))
    out = ["| arch | shape | mesh | compute s | memory s | collective s |"
           " dominant | useful | MFU* | GiB/dev |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for a in rows:
        name = ("KFAC:" if a["kfac"] else "") + a["arch"]
        out.append(
            f"| {name} | {a['shape']} | {a['mesh']} | "
            f"{a['compute_s']:.4f} | {a['memory_s']:.4f} | "
            f"{a['collective_s']:.4f} | {a['dominant']} | "
            f"{a['useful_ratio']:.3f} | {a['mfu_model']:.3f} | "
            f"{a['gib_per_dev']:.2f} |")
    return "\n".join(out)


if __name__ == "__main__":
    import sys
    if len(sys.argv) > 1 and sys.argv[1] == "--markdown":
        print(markdown_table())
    else:
        run_csv()
