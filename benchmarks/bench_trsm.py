"""Fig. 5 — Recursive TRSM speedup (X = B L^{-T}).

Measured: CPU wall-time of tree-TRSM vs jax.scipy solve_triangular.
Derived: v5e-modeled speedup (census) + GEMM fraction per config.
"""
from __future__ import annotations

import functools

import jax
import numpy as np

from benchmarks.util import emit, model_time_s, timeit
from repro.core import PrecisionConfig, census_trsm, tree_trsm

CONFIGS = {
    "f32": PrecisionConfig(levels=("f32",), leaf=128),
    "bf16_f32": PrecisionConfig(levels=("bf16", "f32"), leaf=128),
    "f16_f32": PrecisionConfig(levels=("f16", "f32"), leaf=128),
    "f16x3_f32": PrecisionConfig(levels=("f16",) * 3 + ("f32",), leaf=128),
    "pure_f16": PrecisionConfig(levels=("f16",), leaf=128),
}


def run(sizes=(512, 1024, 2048)):
    for n in sizes:
        m = n
        rng = np.random.default_rng(0)
        l = np.tril(rng.standard_normal((n, n))).astype(np.float32)
        l[np.diag_indices(n)] += n ** 0.5
        b = rng.standard_normal((m, n)).astype(np.float32)

        def base_fn(b, l):
            y = jax.scipy.linalg.solve_triangular(l, b.T, lower=True)
            return y.T

        base = jax.jit(base_fn)
        t_base = timeit(base, b, l)
        emit(f"trsm_baseline_lapack_f32_n{n}", t_base, "speedup=1.00")

        cen32 = census_trsm(m, n, CONFIGS["f32"])
        t32_model = model_time_s(cen32)
        for name, cfg in CONFIGS.items():
            fn = jax.jit(functools.partial(tree_trsm, cfg=cfg))
            t = timeit(fn, b, l)
            cen = census_trsm(m, n, cfg)
            model_speedup = t32_model / model_time_s(cen)
            emit(f"trsm_tree_{name}_n{n}", t,
                 f"model_v5e_speedup={model_speedup:.2f};"
                 f"gemm_frac={cen.gemm_fraction:.3f};"
                 f"cpu_speedup={t_base / t:.2f}")


if __name__ == "__main__":
    run()
