"""Iterative-refinement benchmark: digits recovered per sweep and
time-to-tolerance for every PAPER_CONFIGS ladder.

For each ladder this measures
  * the one-off factorization time (the O(n^3) part the ladder makes
    cheap),
  * the per-sweep IR cost (two tree-TRSMs + residual GEMM, O(n^2)),
  * digits of relative residual before refinement, after refinement,
    and the digits-recovered-per-sweep rate,
  * time-to-tolerance: wall time of the jitted refine loop.

Run under JAX_ENABLE_X64=1 (run.py does this via subprocess) so the
residual precision is f64 and the tolerance target is meaningful;
without x64 the target degrades to the f32 floor automatically.

Smoke mode (REPRO_BENCH_SMOKE=1 or run.py --smoke) shrinks sizes so the
CI bench job finishes in seconds.
"""
from __future__ import annotations

import functools
import os
import sys

import jax
import numpy as np

# allow `python benchmarks/bench_refine.py` (script dir shadows the root)
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

from benchmarks.util import emit, spd_matrix, timeit  # noqa: E402
from repro.core import (PAPER_CONFIGS, RefineConfig, cholesky,  # noqa: E402
                        iterative_refine)

#: ladders benchmarked; f64 entries need x64, int8 rides the integer path
SKIP = ("pure_f64",)  # identical to the reference — nothing to refine


def _tol():
    return 1e-10 if jax.config.jax_enable_x64 else 1e-6


def _digits(relres: float) -> float:
    return -np.log10(max(float(relres), 1e-17))


def run(sizes=(1024, 2048), methods=("ir", "gmres")):
    tol = _tol()
    for n in sizes:
        a = spd_matrix(
            n, dtype=np.float64 if jax.config.jax_enable_x64
            else np.float32)
        b = a @ np.random.default_rng(0).standard_normal(n).astype(a.dtype)
        for name, cfg in PAPER_CONFIGS.items():
            if name in SKIP:
                continue
            if cfg.high_name == "f64" and not jax.config.jax_enable_x64:
                continue
            fac = jax.jit(functools.partial(cholesky, cfg=cfg))
            t_factor = timeit(fac, a.astype(np.float32)
                              if cfg.high_name != "f64" else a)
            for method in methods:
                rcfg = RefineConfig(max_sweeps=5, tol=tol, method=method,
                                    gmres_restart=8)
                fn = jax.jit(functools.partial(
                    iterative_refine, cfg=cfg, refine=rcfg))
                res = fn(a, b)
                t_refine = timeit(fn, a, b)
                hist = np.asarray(res.history, np.float64)
                sweeps = int(res.iterations)
                d0, d1 = _digits(hist[0]), _digits(res.residual)
                rate = (d1 - d0) / max(sweeps, 1)
                emit(f"refine_{method}_{name}_n{n}", t_refine,
                     f"digits0={d0:.2f};digits={d1:.2f};sweeps={sweeps};"
                     f"digits_per_sweep={rate:.2f};"
                     f"converged={bool(res.converged)};"
                     f"factor_us={t_factor:.1f};tol={tol:g}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes, IR only (CI)")
    args = ap.parse_args()
    if args.smoke or os.environ.get("REPRO_BENCH_SMOKE") == "1":
        run(sizes=(256,), methods=("ir",))
    else:
        run()
