"""Shared benchmark utilities: timing + CSV emission + TPU model.

Every bench prints ``name,us_per_call,derived`` rows (assignment
contract). Wall times are CPU (this container); the `derived` column
carries the figure-specific quantity (speedup, digits, modeled TPU
speedup, flop fractions). TPU-projected numbers come from the structural
census (repro.core.census) + v5e peaks and are always labelled model_*.
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro.core.precision import PEAK_FLOPS

HBM_BW = 819e9          # bytes/s per chip (v5e)

#: rows emitted so far (run.py serializes these as the JSON artifact)
ROWS: list[dict] = []


def smoke_mode() -> bool:
    """True when run.py --smoke (or CI) asked for tiny benchmark sizes."""
    return os.environ.get("REPRO_BENCH_SMOKE") == "1"


def timeit(fn, *args, warmup=2, iters=5):
    """Median wall-time in microseconds of a jitted callable."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def emit(name: str, us: float, derived):
    ROWS.append({"name": name, "us_per_call": round(float(us), 1),
                 "derived": str(derived)})
    print(f"{name},{us:.1f},{derived}")


def spd_matrix(n, dtype=np.float32, seed=0):
    """Paper §IV-A: uniform entries, +n on the diagonal."""
    rng = np.random.default_rng(seed)
    m = rng.uniform(-1.0, 1.0, (n, n))
    a = (m + m.T) / 2
    a[np.diag_indices(n)] += n
    return a.astype(dtype)


def model_time_s(census, *, include_memory=True):
    """v5e time model from a structural census: compute term per
    precision level + HBM term (bf16/f16 halve the bytes)."""
    t = 0.0
    for k, v in census.gemm_flops.items():
        t += v / PEAK_FLOPS[k]
    for k, v in census.leaf_flops.items():
        t += v / PEAK_FLOPS[k]
    if include_memory:
        t += sum(census.gemm_bytes.values()) / HBM_BW
    return t
