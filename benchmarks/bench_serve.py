"""Solve-serving benchmark: cross-request batching + fused residual.

Measures the two serve-side claims of the batched request loop:

  * requests/sec vs. batch size — R requests sharing a factor, solved
    sequentially (one ``SolverEngine.solve`` per request) vs. batched
    through the :class:`~repro.serve.scheduler.BatchScheduler` (one
    multi-RHS refine call with per-column convergence). Each sequential
    sweep is an O(n^2) GEMV + dispatch round-trip per request; the
    batched sweep is one BLAS3-shaped GEMM for the whole batch. GATED:
    batched must beat sequential once >= 4 requests share a factor.
  * continuous vs. window batching — staggered mixed-target arrivals
    against an oversubscribed slot block; continuous batching (mid-
    flight column join/retire) must sustain req/s >= the windowed
    scheduler at r >= 8 (gated by ``tools/perf_gate.py serve`` in CI).
  * fused vs. unfused residual — the Pallas ``r = b - A x`` kernel
    against the XLA oracle, REQUIRED to agree allclose in the residual
    dtype (the acceptance gate; on CPU the fused kernel runs in
    interpret mode, so the comparison is correctness + reference timing,
    not a speed claim — the speed path is the TPU MXU).

Smoke mode (REPRO_BENCH_SMOKE=1, --smoke, or run.py --smoke) shrinks
sizes so the CI bench job finishes in seconds; ``--out`` writes the rows
as a JSON artifact (CI uploads it on every PR).
"""
from __future__ import annotations

import json
import os
import sys

import jax
import numpy as np

# allow `python benchmarks/bench_serve.py` (script dir shadows the root)
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

from benchmarks.util import emit, spd_matrix, timeit  # noqa: E402
from repro.kernels import ops, ref  # noqa: E402
from repro.serve import (BatchScheduler, SolveOptions,  # noqa: E402
                         SolverEngine)

LADDER = "f16_f32"
_OPTS6 = SolveOptions(target_digits=6.0, cache_key="bench")


def _bench_request_loop(n, counts, ladder=LADDER):
    a = spd_matrix(n)
    rng = np.random.default_rng(0)
    eng = SolverEngine(ladder, max_sweeps=8)
    eng.factor(a, cache_key="bench")     # exclude the one-off O(n^3) cost
    for r in counts:
        bs = [(a @ rng.standard_normal(n)).astype(np.float32)
              for _ in range(r)]

        def seq():
            return [eng.solve(a, b, _OPTS6)[0] for b in bs]

        sch = BatchScheduler(eng, max_batch=max(counts))

        def batched():
            for b in bs:
                sch.submit(a, b, _OPTS6)
            return [x for x, _ in sch.drain().values()]

        t_seq = timeit(seq, warmup=1, iters=3)
        t_bat = timeit(batched, warmup=1, iters=3)
        speedup = t_seq / t_bat
        emit(f"serve_seq_{ladder}_n{n}_r{r}", t_seq,
             f"req_per_s={r / (t_seq * 1e-6):.1f}")
        emit(f"serve_batched_{ladder}_n{n}_r{r}", t_bat,
             f"req_per_s={r / (t_bat * 1e-6):.1f};"
             f"speedup_vs_seq={speedup:.2f}")
        # acceptance gate: batching must beat sequential once >=4
        # requests share a factor (typical margin is 3-6x, so a 1.0
        # threshold leaves plenty of room for noisy CI runners)
        if r >= 4 and speedup < 1.0:
            raise AssertionError(
                f"batched serving slower than sequential at n={n}, "
                f"r={r}: speedup {speedup:.2f}")


def _bench_continuous(n, r, ladder=LADDER):
    """Staggered-arrival continuous-vs-window race — the headline row.

    R requests with mixed accuracy targets (alternating 3 / 6 digits)
    arrive 2 ms apart against ``slots = r // 2`` capacity, so the block
    is always oversubscribed. The windowed scheduler makes each request
    wait for its batching window and holds every window open for its
    slowest member; the continuous scheduler joins arrivals mid-flight
    and retires easy columns early, freeing their slots. Rows carry
    ``req_per_s`` and ``speedup_vs_window``; ``tools/perf_gate.py
    serve`` gates continuous >= window at r >= 8 (per-column accuracy
    is asserted here — every request must report ``converged``).
    """
    import time

    a = spd_matrix(n)
    rng = np.random.default_rng(2)
    slots = max(2, r // 2)
    eng = SolverEngine(ladder, max_sweeps=8)
    eng.factor(a, cache_key="bench")     # exclude the one-off O(n^3) cost
    bs = [(a @ rng.standard_normal(n)).astype(np.float32)
          for _ in range(r)]
    opts = [SolveOptions(target_digits=(3.0 if i % 2 else 6.0),
                         cache_key="bench") for i in range(r)]

    def race(sch):
        sch.start()
        try:
            t0 = time.perf_counter()
            futs = []
            for b, o in zip(bs, opts):
                futs.append(sch.submit_async(a, b, o))
                time.sleep(2e-3)         # staggered arrivals
            outs = [f.result(timeout=300) for f in futs]
            wall = time.perf_counter() - t0
        finally:
            sch.stop()
        bad = [i for i, (_, info) in enumerate(outs) if not info.converged]
        assert not bad, f"requests missed their accuracy target: {bad}"
        return wall * 1e6

    walls = {}
    for mode in ("window", "continuous"):
        def mk():
            if mode == "window":
                return BatchScheduler(eng, max_batch=slots,
                                      max_wait_ms=10.0)
            return BatchScheduler(eng, max_batch=slots, continuous=True)
        race(mk())                       # warmup: compile the refine paths
        walls[mode] = sorted(race(mk()) for _ in range(3))[1]   # median
    t_win, t_cont = walls["window"], walls["continuous"]
    speedup = t_win / t_cont
    emit(f"serve_window_{ladder}_n{n}_r{r}", t_win,
         f"req_per_s={r / (t_win * 1e-6):.1f};slots={slots}")
    emit(f"serve_continuous_{ladder}_n{n}_r{r}", t_cont,
         f"req_per_s={r / (t_cont * 1e-6):.1f};"
         f"speedup_vs_window={speedup:.2f};converged=True;slots={slots}")


def _bench_residual(n, k=8):
    """Fused-vs-XLA residual: allclose gate + timings."""
    rng = np.random.default_rng(1)
    a = rng.standard_normal((n, n)).astype(np.float32)
    x = rng.standard_normal((n, k)).astype(np.float32)
    b = rng.standard_normal((n, k)).astype(np.float32)
    fused_impl = "pallas" if jax.default_backend() == "tpu" else "interpret"
    r_ref = ref.residual_ref(a, x, b)
    r_fused = ops.residual(a, x, b, impl=fused_impl)
    diff = float(np.max(np.abs(np.asarray(r_fused, np.float64)
                               - np.asarray(r_ref, np.float64))))
    scale = float(np.max(np.abs(np.asarray(r_ref))))
    ok = bool(np.allclose(np.asarray(r_fused), np.asarray(r_ref),
                          rtol=2e-4, atol=2e-4 * max(scale, 1.0)))
    t_ref = timeit(lambda: ops.residual(a, x, b, impl="jnp"))
    t_fused = timeit(lambda: ops.residual(a, x, b, impl=fused_impl))
    emit(f"serve_residual_fused_n{n}_k{k}", t_fused,
         f"allclose={ok};max_abs_diff={diff:.3e};xla_us={t_ref:.1f};"
         f"impl={fused_impl}")
    if not ok:  # the acceptance gate: fused must match the XLA fallback
        raise AssertionError(
            f"fused residual diverged from XLA oracle: {diff:.3e}")


def run(sizes=(512, 1024), counts=(1, 2, 4, 8, 16)):
    for n in sizes:
        _bench_request_loop(n, counts)
    for r in [c for c in counts if c >= 8]:
        _bench_continuous(min(sizes), r)
    _bench_residual(max(sizes))


if __name__ == "__main__":
    import argparse

    from benchmarks import util

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes (CI bench-smoke job)")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write rows as a JSON artifact")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.smoke or os.environ.get("REPRO_BENCH_SMOKE") == "1":
        run(sizes=(256,), counts=(1, 4, 8))
    else:
        run()
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"smoke": bool(args.smoke), "rows": list(util.ROWS)},
                      f, indent=1)
        print(f"# wrote {len(util.ROWS)} rows to {args.out}",
              file=sys.stderr)
