"""Benchmark aggregator: one bench per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (assignment contract).

  bench_syrk         Fig. 4   recursive SYRK speedup
  bench_trsm         Fig. 5   recursive TRSM speedup
  bench_cholesky     Fig. 6/7 Cholesky throughput + speedup
  bench_accuracy     Fig. 8   precision-ladder digits (x64 subprocess)
  bench_refine       beyond-paper IR digits/sweep (x64 subprocess)
  bench_serve        beyond-paper batched solve serving + fused residual
  bench_depth        Fig. 10  size/depth scaling
  bench_portability  Fig. 9/11 backend dispatch agreement
  bench_dist         beyond-paper multi-chip solver (4-dev subprocess;
                     writes BENCH_dist.json for CI's dist gate)

Accuracy, refinement and distributed benches need different
process-level settings (x64 / forced device count), so run.py re-execs
them as subprocesses.

``--smoke`` shrinks every bench to CI-sized problems (propagated to
subprocesses via REPRO_BENCH_SMOKE=1); ``--out results.json`` writes all
rows as a JSON artifact so CI tracks the perf trajectory per PR.

``--tune`` runs the measured-search autotuner (repro.tune) instead of
the benches: a 4-device subprocess regenerates the committed tuning
database at ``src/repro/tune/data/<backend>.json`` (``--tune-out``
overrides the path). ``--tune --smoke`` shrinks the search grid and
writes ``tuned-smoke.json`` instead — smoke data never silently
replaces the committed database.
"""
from __future__ import annotations

import argparse
import contextlib
import io
import json
import os
import subprocess
import sys

# allow `python benchmarks/run.py` (script dir shadows the repo root)
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)


def _parse_rows(text: str):
    rows = []
    for line in text.splitlines():
        parts = line.strip().split(",", 2)
        if len(parts) == 3 and parts[0] != "name":
            try:
                us = float(parts[1])
            except ValueError:
                continue
            rows.append({"name": parts[0], "us_per_call": us,
                         "derived": parts[2]})
    return rows


def _sub(module: str, env_extra: dict):
    env = dict(os.environ)
    env.setdefault("PYTHONPATH", "src")
    env.update(env_extra)
    r = subprocess.run([sys.executable, "-m", module], env=env,
                       capture_output=True, text=True, timeout=3000)
    sys.stdout.write(r.stdout)
    rows = _parse_rows(r.stdout)
    if r.returncode != 0:
        # the failure marker must reach the JSON artifact too, so a
        # crashed bench reads as FAILED rather than silently-absent rows
        sys.stdout.write(f"{module},0.0,FAILED\n")
        sys.stderr.write(r.stderr[-2000:])
        rows.append({"name": module, "us_per_call": 0.0,
                     "derived": "FAILED"})
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny problem sizes (CI benchmark-smoke job)")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write all rows as a JSON artifact")
    ap.add_argument("--tune", action="store_true",
                    help="run the autotuner (repro.tune) instead of the "
                         "benches; writes the tuning database")
    ap.add_argument("--tune-out", default=None, metavar="PATH",
                    help="tuning-database path (default: the committed "
                         "src/repro/tune/data/<backend>.json; with "
                         "--smoke: ./tuned-smoke.json)")
    args = ap.parse_args(argv)
    if args.smoke:
        os.environ["REPRO_BENCH_SMOKE"] = "1"

    if args.tune:
        # own process: the tuner needs the forced 4-device mesh from the
        # very first jax import, same as the distributed bench
        env = dict(os.environ)
        env.setdefault("PYTHONPATH", "src")
        env.setdefault("XLA_FLAGS",
                       "--xla_force_host_platform_device_count=4")
        cmd = [sys.executable, "-m", "repro.tune"]
        if args.smoke:
            cmd.append("--smoke")
        out = args.tune_out
        if out is None and args.smoke:
            # smoke grids are for validating the tuner wiring, not for
            # producing winners — never clobber the committed database
            out = "tuned-smoke.json"
        if out:
            cmd += ["--out", out]
        raise SystemExit(subprocess.run(cmd, env=env).returncode)

    print("name,us_per_call,derived")
    from benchmarks import (bench_cholesky, bench_depth, bench_portability,
                            bench_serve, bench_syrk, bench_trsm, util)
    if args.smoke:
        bench_syrk.run(sizes=(256,))
        bench_trsm.run(sizes=(256,))
        bench_cholesky.run(sizes=(256,))
        # tree-vs-blocked engine race; writes BENCH_cholesky.json at the
        # repo root (CI's perf gate asserts blocked >= tree at n >= 2048)
        bench_cholesky.run_engines(sizes=(512, 2048))
        bench_depth.run(sizes=(256, 1024, 4096))
        bench_portability.run(sizes=(256,))
        # bench_serve is skipped in smoke mode: CI's bench-smoke job runs
        # it as its own step (bench_serve.py --smoke --out bench-serve.json)
    else:
        bench_syrk.run()
        bench_trsm.run()
        bench_cholesky.run()
        bench_cholesky.run_engines(sizes=(512, 2048, 4096))
        bench_depth.run()
        bench_portability.run()
        bench_serve.run()
    sub_rows = _sub("benchmarks.bench_accuracy", {"JAX_ENABLE_X64": "1"})
    sub_rows += _sub("benchmarks.bench_refine", {"JAX_ENABLE_X64": "1"})
    sub_rows += _sub(
        "benchmarks.bench_dist",
        {"XLA_FLAGS": "--xla_force_host_platform_device_count=4"})
    # roofline table (reads experiments/dryrun if present); it prints
    # rows directly, so tee its stdout into the artifact rows as well
    try:
        from benchmarks import roofline
        buf = io.StringIO()
        with contextlib.redirect_stdout(buf):
            roofline.run_csv()
        sys.stdout.write(buf.getvalue())
        sub_rows += _parse_rows(buf.getvalue())
    except Exception as e:  # noqa: BLE001
        print(f"roofline,0.0,unavailable({type(e).__name__})")
        sub_rows.append({"name": "roofline", "us_per_call": 0.0,
                         "derived": f"unavailable({type(e).__name__})"})

    if args.out:
        payload = {"smoke": args.smoke, "rows": list(util.ROWS) + sub_rows}
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {len(payload['rows'])} rows to {args.out}",
              file=sys.stderr)


if __name__ == "__main__":
    main()
