"""Benchmark aggregator: one bench per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (assignment contract).

  bench_syrk         Fig. 4   recursive SYRK speedup
  bench_trsm         Fig. 5   recursive TRSM speedup
  bench_cholesky     Fig. 6/7 Cholesky throughput + speedup
  bench_accuracy     Fig. 8   precision-ladder digits (x64 subprocess)
  bench_depth        Fig. 10  size/depth scaling
  bench_portability  Fig. 9/11 backend dispatch agreement
  bench_dist         beyond-paper multi-chip solver (8-dev subprocess)

Accuracy and distributed benches need different process-level settings
(x64 / forced device count), so run.py re-execs them as subprocesses.
"""
from __future__ import annotations

import os
import subprocess
import sys


def _sub(module: str, env_extra: dict):
    env = dict(os.environ)
    env.setdefault("PYTHONPATH", "src")
    env.update(env_extra)
    r = subprocess.run([sys.executable, "-m", module], env=env,
                       capture_output=True, text=True, timeout=3000)
    sys.stdout.write(r.stdout)
    if r.returncode != 0:
        sys.stdout.write(f"{module},0.0,FAILED\n")
        sys.stderr.write(r.stderr[-2000:])


def main() -> None:
    print("name,us_per_call,derived")
    from benchmarks import (bench_cholesky, bench_depth, bench_portability,
                            bench_syrk, bench_trsm)
    bench_syrk.run()
    bench_trsm.run()
    bench_cholesky.run()
    bench_depth.run()
    bench_portability.run()
    _sub("benchmarks.bench_accuracy", {"JAX_ENABLE_X64": "1"})
    _sub("benchmarks.bench_dist",
         {"XLA_FLAGS": "--xla_force_host_platform_device_count=8"})
    # roofline table (reads experiments/dryrun if present)
    try:
        from benchmarks import roofline
        roofline.run_csv()
    except Exception as e:  # noqa: BLE001
        print(f"roofline,0.0,unavailable({type(e).__name__})")


if __name__ == "__main__":
    main()
