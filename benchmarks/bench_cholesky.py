"""Figs. 6 + 7 — Cholesky throughput (effective TFLOP/s) and speedup.

Measured: CPU wall-time of tree-POTRF vs jnp.linalg.cholesky; effective
GFLOP/s = (n^3/3) / t.
Derived: v5e-modeled effective TFLOP/s and speedup over the uniform-f32
tree (census compute+memory model), Fig. 6's "peak-utilization is not
the right objective" trade-off reproduced as model numbers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.util import emit, model_time_s, spd_matrix, timeit
from repro.core import PrecisionConfig, census_potrf, cholesky

CONFIGS = {
    "f32": PrecisionConfig(levels=("f32",), leaf=128),
    "f32x3_f64": PrecisionConfig(levels=("f32",) * 3 + ("f64",), leaf=128),
    "bf16_f32": PrecisionConfig(levels=("bf16", "f32"), leaf=128),
    "f16_f32": PrecisionConfig(levels=("f16", "f32"), leaf=128),
    "f16x3_f32": PrecisionConfig(levels=("f16",) * 3 + ("f32",), leaf=128),
    "f16x5_f32": PrecisionConfig(levels=("f16",) * 5 + ("f32",), leaf=128),
    "pure_f16": PrecisionConfig(levels=("f16",), leaf=128),
    # beyond-paper int8 ladder (v5e double-rate integer MXU path)
    "int8x3_f32": PrecisionConfig(levels=("int8",) * 3 + ("f32",),
                                  leaf=128),
}


def run(sizes=(512, 1024, 2048)):
    for n in sizes:
        a = spd_matrix(n)
        flops = n ** 3 / 3

        base = jax.jit(jnp.linalg.cholesky)
        t_base = timeit(base, a)
        emit(f"potrf_baseline_lapack_f32_n{n}", t_base,
             f"gflops={flops / t_base / 1e3:.2f};speedup=1.00")

        t32_model = model_time_s(census_potrf(n, CONFIGS["f32"]))
        for name, cfg in CONFIGS.items():
            if "f64" in name and not jax.config.jax_enable_x64:
                continue
            fn = jax.jit(functools.partial(cholesky, cfg=cfg))
            t = timeit(fn, a)
            cen = census_potrf(n, cfg)
            tm = model_time_s(cen)
            emit(f"potrf_tree_{name}_n{n}", t,
                 f"gflops={flops / t / 1e3:.2f};"
                 f"model_v5e_tflops={flops / tm / 1e12:.2f};"
                 f"model_v5e_speedup={t32_model / tm:.2f};"
                 f"cpu_speedup={t_base / t:.2f}")


if __name__ == "__main__":
    run()
