"""Figs. 6 + 7 — Cholesky throughput (effective TFLOP/s) and speedup.

Measured: CPU wall-time of tree-POTRF vs jnp.linalg.cholesky; effective
GFLOP/s = (n^3/3) / t.
Derived: v5e-modeled effective TFLOP/s and speedup over the uniform-f32
tree (census compute+memory model), Fig. 6's "peak-utilization is not
the right objective" trade-off reproduced as model numbers.

``run_engines`` (PR 3) races the flat blocked executor against the tree
recursion on identical ladders — wall clock plus traced jaxpr equation
counts (the dispatch DAG each engine hands XLA) — and writes the
``BENCH_cholesky.json`` artifact at the repo root that CI's
blocked-vs-tree perf gate reads.
"""
from __future__ import annotations

import functools
import json
import os

import jax
import jax.numpy as jnp

from benchmarks.util import emit, model_time_s, spd_matrix, timeit
from repro.core import PrecisionConfig, census_potrf, cholesky

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CONFIGS = {
    "f32": PrecisionConfig(levels=("f32",), leaf=128),
    "f32x3_f64": PrecisionConfig(levels=("f32",) * 3 + ("f64",), leaf=128),
    "bf16_f32": PrecisionConfig(levels=("bf16", "f32"), leaf=128),
    "f16_f32": PrecisionConfig(levels=("f16", "f32"), leaf=128),
    "f16x3_f32": PrecisionConfig(levels=("f16",) * 3 + ("f32",), leaf=128),
    "f16x5_f32": PrecisionConfig(levels=("f16",) * 5 + ("f32",), leaf=128),
    "pure_f16": PrecisionConfig(levels=("f16",), leaf=128),
    # beyond-paper int8 ladder (v5e double-rate integer MXU path)
    "int8x3_f32": PrecisionConfig(levels=("int8",) * 3 + ("f32",),
                                  leaf=128),
}


def run(sizes=(512, 1024, 2048)):
    for n in sizes:
        a = spd_matrix(n)
        flops = n ** 3 / 3

        base = jax.jit(jnp.linalg.cholesky)
        t_base = timeit(base, a)
        emit(f"potrf_baseline_lapack_f32_n{n}", t_base,
             f"gflops={flops / t_base / 1e3:.2f};speedup=1.00")

        t32_model = model_time_s(census_potrf(n, CONFIGS["f32"]))
        for name, cfg in CONFIGS.items():
            if "f64" in name and not jax.config.jax_enable_x64:
                continue
            fn = jax.jit(functools.partial(cholesky, cfg=cfg))
            t = timeit(fn, a)
            cen = census_potrf(n, cfg)
            tm = model_time_s(cen)
            emit(f"potrf_tree_{name}_n{n}", t,
                 f"gflops={flops / t / 1e3:.2f};"
                 f"model_v5e_tflops={flops / tm / 1e12:.2f};"
                 f"model_v5e_speedup={t32_model / tm:.2f};"
                 f"cpu_speedup={t_base / t:.2f}")


def run_engines(sizes=(512, 2048), ladder=("bf16", "f32"), leaf=256,
                json_path=None):
    """Tree vs blocked engine race on one ladder: wall clock, speedup,
    and jaxpr equation counts. Writes ``BENCH_cholesky.json`` (repo
    root) for CI's perf gate: blocked slower than tree at n >= 2048 is
    a regression."""
    rows = []
    for n in sizes:
        a = spd_matrix(n)
        row = {"n": n, "ladder": "_".join(ladder), "leaf": leaf}
        for eng in ("tree", "blocked"):
            cfg = PrecisionConfig(levels=ladder, leaf=leaf, engine=eng)
            fn = functools.partial(cholesky, cfg=cfg)
            # the tree's concat-heavy allocation pattern is noisy on
            # shared CI runners: median over more iters than the default
            t = timeit(jax.jit(fn), a, warmup=3, iters=9)
            eqns = len(jax.make_jaxpr(fn)(jnp.asarray(a)).eqns)
            row[f"us_{eng}"] = round(t, 1)
            row[f"eqns_{eng}"] = eqns
            emit(f"potrf_engine_{eng}_n{n}", t, f"jaxpr_eqns={eqns}")
        row["speedup_blocked_vs_tree"] = round(
            row["us_tree"] / row["us_blocked"], 3)
        emit(f"potrf_engine_speedup_n{n}", row["us_blocked"],
             f"speedup_blocked_vs_tree={row['speedup_blocked_vs_tree']};"
             f"eqns_tree={row['eqns_tree']};"
             f"eqns_blocked={row['eqns_blocked']}")
        rows.append(row)
    path = json_path or os.path.join(_ROOT, "BENCH_cholesky.json")
    with open(path, "w") as f:
        json.dump({"bench": "cholesky_engines", "rows": rows}, f, indent=1)
    return rows


if __name__ == "__main__":
    run()
    run_engines()
