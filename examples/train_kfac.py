"""End-to-end training driver: a ~100M-parameter dense LM trained for a
few hundred steps with the TreeNewton optimizer — the paper's solver
factorizing the Kronecker preconditioner blocks every ``factor_every``
steps — with checkpoint/resume and an AdamW comparison.

    PYTHONPATH=src python examples/train_kfac.py \
        [--steps 300] [--optimizer tree_newton|adamw] [--resume]

CPU note: ~100M params trains at a few steps/s here; the same script on
a TPU pod only changes the mesh/sharder wiring (see repro/launch).
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro.data import SyntheticLM
from repro.models.common import ModelConfig
from repro.optim import AdamWConfig, TreeNewtonConfig
from repro.train import TrainConfig, init_state, make_train_step


def model_100m():
    return ModelConfig(
        name="lm-100m", family="dense", n_layers=8, d_model=512,
        d_ff=2048, vocab=32768, n_heads=8, n_kv=4, mlp="swiglu",
        max_seq=512, remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--optimizer", default="tree_newton",
                    choices=("tree_newton", "adamw"))
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = model_100m()
    adam = AdamWConfig(lr=3e-3, warmup=20, total_steps=args.steps)
    tn = TreeNewtonConfig(adam=adam, block=256, factor_every=20,
                          stats_every=2)
    tcfg = TrainConfig(optimizer=args.optimizer, adam=adam, tree_newton=tn)

    state = init_state(jax.random.PRNGKey(0), cfg, tcfg)
    n = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"model: {n / 1e6:.1f}M params, optimizer={args.optimizer}")

    start = 0
    if args.resume and ckpt.latest_step(args.ckpt_dir) is not None:
        state, start = ckpt.restore(args.ckpt_dir, state)
        print(f"resumed from step {start}")

    data = SyntheticLM(cfg.vocab, args.batch, args.seq, seed=0)
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))

    t0 = time.time()
    handle = None
    for i in range(start, args.steps):
        batch = jax.tree.map(jnp.asarray, data.get(i))
        state, m = step_fn(state, batch)
        if (i + 1) % 20 == 0:
            dt = (time.time() - t0) / (i + 1 - start)
            print(f"step {i + 1:4d}  loss={float(m['loss']):7.4f}  "
                  f"gnorm={float(m['grad_norm']):7.3f}  "
                  f"lr={float(m['lr']):.2e}  {dt * 1e3:6.0f} ms/step")
        if (i + 1) % args.ckpt_every == 0:
            handle = ckpt.save(args.ckpt_dir, i + 1, state)  # async
    if handle:
        handle.wait()
    print("done; resume any time with --resume "
          f"(checkpoints in {args.ckpt_dir})")


if __name__ == "__main__":
    main()
