"""Serving example: batched prefill + greedy decode on any assigned
architecture's smoke config (full configs serve identically on a pod —
see repro/launch/dryrun.py decode cells).

    PYTHONPATH=src python examples/serve.py --arch deepseek-v2-lite-16b

``--solver`` instead demos the linear-algebra serving loop: a stream of
accuracy-targeted SPD solve requests sharing a kernel matrix (the GP
hyperparameter-sweep shape of traffic) submitted to a BatchScheduler,
which batches them into one multi-RHS refine call against a cached,
fingerprint-checked factor:

    PYTHONPATH=src python examples/serve.py --solver --requests 8
"""
import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import transformer as T
from repro.serve import (BatchScheduler, SolveOptions,
                         SolverEngine, generate)


def solver_demo(n: int, n_requests: int, ladder: str):
    rng = np.random.default_rng(0)
    m = rng.uniform(-1, 1, (n, n))
    a = (m @ m.T + n * np.eye(n)).astype(np.float32)
    bs = [(a @ rng.standard_normal(n)).astype(np.float32)
          for _ in range(n_requests)]
    # mixed per-request accuracy targets survive batching (per-column
    # tolerances + convergence masks in the stacked refine call)
    targets = [3.0 if i % 2 else 6.0 for i in range(n_requests)]

    eng = SolverEngine(ladder, max_sweeps=8)
    sch = BatchScheduler(eng, max_batch=32)
    # pre-factor so both timers measure serving, not the one-off O(n^3)
    eng.factor(a, cache_key="demo")

    t0 = time.time()
    seq = [eng.solve(a, b, SolveOptions(target_digits=t,
                                    cache_key="demo"))
           for b, t in zip(bs, targets)]
    t_seq = time.time() - t0

    t0 = time.time()
    ids = [sch.submit(a, b, SolveOptions(target_digits=t,
                                     cache_key="demo"))
           for b, t in zip(bs, targets)]
    out = sch.drain()
    t_bat = time.time() - t0

    print(f"SolverEngine[{ladder}] n={n}, {n_requests} requests "
          f"sharing one factor:")
    print(f"  sequential : {t_seq:.3f}s ({n_requests / t_seq:.1f} req/s)")
    print(f"  batched    : {t_bat:.3f}s ({n_requests / t_bat:.1f} req/s, "
          f"{t_seq / max(t_bat, 1e-9):.2f}x)")
    for rid, b, t in zip(ids, bs, targets):
        x, info = out[rid]
        rr = np.linalg.norm(a @ np.asarray(x) - b) / np.linalg.norm(b)
        print(f"  req {rid}: target={t:.0f} digits  sweeps={info.sweeps}  "
              f"rel_res={rr:.1e}  batch={info.batch_index}/"
              f"{info.batch_size}  converged={info.converged}")
    assert all(np.allclose(np.asarray(out[r][0]), np.asarray(s[0]),
                           rtol=1e-4, atol=1e-5)
               for r, s in zip(ids, seq))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=configs.ARCHS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--solver", action="store_true",
                    help="demo the batched SPD solve request loop")
    ap.add_argument("--n", type=int, default=512,
                    help="--solver: matrix size")
    ap.add_argument("--requests", type=int, default=8,
                    help="--solver: concurrent solve requests")
    ap.add_argument("--ladder", default="f16_f32",
                    help="--solver: factorization precision ladder")
    args = ap.parse_args()

    if args.solver:
        solver_demo(args.n, args.requests, args.ladder)
        return

    cfg = configs.get_config(args.arch, smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)

    shape = ((args.batch, args.prompt_len, cfg.n_codebooks)
             if cfg.family == "audio" else (args.batch, args.prompt_len))
    prompt = {"tokens": jax.random.randint(jax.random.PRNGKey(1), shape,
                                           0, cfg.vocab)}
    if cfg.family == "vlm":
        prompt["patch_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.n_img_tokens, cfg.d_model))

    t0 = time.time()
    out = generate(params, prompt, cfg, n_tokens=args.new_tokens,
                          max_len=args.prompt_len + args.new_tokens)
    dt = time.time() - t0
    toks = args.batch * args.new_tokens
    print(f"{args.arch} ({cfg.family}): generated {out.shape} in "
          f"{dt:.2f}s ({toks / dt:.1f} tok/s on CPU, smoke config)")
    print("first sequence:", out[0].tolist()[:16], "...")


if __name__ == "__main__":
    main()
