"""Serving example: batched prefill + greedy decode on any assigned
architecture's smoke config (full configs serve identically on a pod —
see repro/launch/dryrun.py decode cells).

    PYTHONPATH=src python examples/serve.py --arch deepseek-v2-lite-16b
"""
import argparse
import time

import jax

from repro import configs
from repro.models import transformer as T
from repro.serve import engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=configs.ARCHS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)

    shape = ((args.batch, args.prompt_len, cfg.n_codebooks)
             if cfg.family == "audio" else (args.batch, args.prompt_len))
    prompt = {"tokens": jax.random.randint(jax.random.PRNGKey(1), shape,
                                           0, cfg.vocab)}
    if cfg.family == "vlm":
        prompt["patch_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.n_img_tokens, cfg.d_model))

    t0 = time.time()
    out = engine.generate(params, prompt, cfg, n_tokens=args.new_tokens,
                          max_len=args.prompt_len + args.new_tokens)
    dt = time.time() - t0
    toks = args.batch * args.new_tokens
    print(f"{args.arch} ({cfg.family}): generated {out.shape} in "
          f"{dt:.2f}s ({toks / dt:.1f} tok/s on CPU, smoke config)")
    print("first sequence:", out[0].tolist()[:16], "...")


if __name__ == "__main__":
    main()
