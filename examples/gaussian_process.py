"""Gaussian-process regression with the mixed-precision tree-Cholesky —
one of the paper's §I motivating applications.

Fits a GP posterior on noisy 1-D data: the kernel matrix solve and the
log-marginal-likelihood (via logdet of the factor) run through the
recursive mixed-precision solver.

    PYTHONPATH=src python examples/gaussian_process.py
"""
import numpy as np

from repro.core import PrecisionConfig, cholesky, logdet, solve_factored

rng = np.random.default_rng(0)
N_TRAIN, N_TEST = 768, 5
NOISE = 0.1


def rbf(xa, xb, ls=0.4):
    d2 = (xa[:, None] - xb[None, :]) ** 2
    return np.exp(-0.5 * d2 / ls ** 2)


x = np.sort(rng.uniform(-3, 3, N_TRAIN))
y = np.sin(2 * x) + 0.5 * np.sin(7 * x) + NOISE * rng.standard_normal(
    N_TRAIN)
xs = np.linspace(-2.5, 2.5, N_TEST)

K = rbf(x, x) + NOISE ** 2 * np.eye(N_TRAIN)
Ks = rbf(x, xs)

# bf16 has f32's exponent range but only an 8-bit mantissa: on an
# ill-conditioned kernel matrix the off-diagonal storage rounding can
# destroy positive-definiteness where f16's 11-bit mantissa survives —
# the range-vs-precision flip side of the paper's f16 quantization story.
# Standard GP practice applies: jitter scaled to the level's epsilon.
JITTER = {"f32": 0.0, "bf16+f32": 4e-2, "f16+f32": 0.0}

for name, levels in [("f32", ("f32",)), ("bf16+f32", ("bf16", "f32")),
                     ("f16+f32", ("f16", "f32"))]:
    K = rbf(x, x) + (NOISE ** 2 + JITTER[name]) * np.eye(N_TRAIN)
    cfg = PrecisionConfig(levels=levels, leaf=128)
    L = cholesky(K.astype(np.float32), cfg)
    alpha = solve_factored(L, y.astype(np.float32)[:, None], cfg)
    mean = Ks.T @ np.asarray(alpha)[:, 0]
    lml = float(-0.5 * y @ np.asarray(alpha)[:, 0]
                - 0.5 * float(logdet(L))
                - 0.5 * N_TRAIN * np.log(2 * np.pi))
    truth = np.sin(2 * xs) + 0.5 * np.sin(7 * xs)
    rmse = np.sqrt(np.mean((mean - truth) ** 2))
    print(f"{name:10s} posterior-mean RMSE={rmse:.4f}  "
          f"log-marginal-likelihood={lml:10.2f}")

print("\nAll three ladders produce the same GP fit — the mixed ladders "
      "just run the O(n^3) part on the MXU at low precision.")
