"""Gaussian-process regression with the mixed-precision tree-Cholesky —
one of the paper's §I motivating applications.

Fits a GP posterior on noisy 1-D data: the kernel matrix solve and the
log-marginal-likelihood (via logdet of the factor) run through the
recursive mixed-precision solver.

    PYTHONPATH=src python examples/gaussian_process.py
"""
import numpy as np

from repro.core import (PrecisionConfig, RefineConfig, cholesky, logdet,
                        refine_solve, solve_factored)

rng = np.random.default_rng(0)
N_TRAIN, N_TEST = 768, 5
NOISE = 0.1


def rbf(xa, xb, ls=0.4):
    d2 = (xa[:, None] - xb[None, :]) ** 2
    return np.exp(-0.5 * d2 / ls ** 2)


x = np.sort(rng.uniform(-3, 3, N_TRAIN))
y = np.sin(2 * x) + 0.5 * np.sin(7 * x) + NOISE * rng.standard_normal(
    N_TRAIN)
xs = np.linspace(-2.5, 2.5, N_TEST)

K = rbf(x, x) + NOISE ** 2 * np.eye(N_TRAIN)
Ks = rbf(x, xs)

# bf16 has f32's exponent range but only an 8-bit mantissa: on an
# ill-conditioned kernel matrix the off-diagonal storage rounding can
# destroy positive-definiteness where f16's 11-bit mantissa survives —
# the range-vs-precision flip side of the paper's f16 quantization story.
# Standard GP practice applies: jitter scaled to the level's epsilon.
JITTER = {"f32": 0.0, "bf16+f32": 4e-2, "f16+f32": 0.0}

print(f"{'ladder':10s} {'RMSE':>8s} {'lml':>10s} "
      f"{'relres':>9s} {'relres_IR':>9s} {'sweeps':>6s}")
for name, levels in [("f32", ("f32",)), ("bf16+f32", ("bf16", "f32")),
                     ("f16+f32", ("f16", "f32"))]:
    K = rbf(x, x) + (NOISE ** 2 + JITTER[name]) * np.eye(N_TRAIN)
    cfg = PrecisionConfig(levels=levels, leaf=128)
    K32 = K.astype(np.float32)
    L = cholesky(K32, cfg)
    alpha = solve_factored(L, y.astype(np.float32)[:, None], cfg)
    res0 = (np.linalg.norm(K @ np.asarray(alpha, np.float64)[:, 0] - y)
            / np.linalg.norm(y))
    # iterative refinement claws back the digits the cheap ladder drops:
    # same factor, a few O(n^2) sweeps (see repro.core.refine). A vector
    # RHS keeps the scalar result contract (multi-RHS blocks report
    # residual/iterations PER COLUMN).
    ref = refine_solve(K32, y.astype(np.float32), cfg,
                       refine=RefineConfig(max_sweeps=5, tol=1e-6), l=L)
    alpha_r = np.asarray(ref.x, np.float64)
    mean = Ks.T @ alpha_r
    lml = float(-0.5 * y @ alpha_r
                - 0.5 * float(logdet(L))
                - 0.5 * N_TRAIN * np.log(2 * np.pi))
    truth = np.sin(2 * xs) + 0.5 * np.sin(7 * xs)
    rmse = np.sqrt(np.mean((mean - truth) ** 2))
    print(f"{name:10s} {rmse:8.4f} {lml:10.2f} "
          f"{res0:9.1e} {float(ref.residual):9.1e} "
          f"{int(ref.iterations):6d}")

print("\nAll three ladders produce the same GP fit; refinement pushes "
      "every ladder's kernel solve to working precision, so the mixed "
      "ladders give f32-quality posteriors at low-precision O(n^3) cost.")
