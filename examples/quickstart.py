"""Quickstart: solve an SPD system with the mixed-precision recursive
Cholesky solver (the paper's contribution, 10 lines of user code).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import PAPER_CONFIGS, PrecisionConfig, cholesky, \
    cholesky_solve

# Build the paper's benchmark matrix: uniform entries, +n on the diagonal
n = 1024
rng = np.random.default_rng(0)
m = rng.uniform(-1, 1, (n, n))
a = (m + m.T) / 2 + n * np.eye(n)
a = a.astype(np.float32)
x_true = rng.standard_normal((n, 4)).astype(np.float32)
b = a @ x_true

print("precision ladder (paper Fig. 2/8):")
for name in ("pure_f32", "bf16_f32", "f16_f32", "f16x3_f32", "pure_f16"):
    cfg = PAPER_CONFIGS[name]
    cfg = PrecisionConfig(levels=cfg.levels, leaf=128)
    x = np.asarray(cholesky_solve(a, b, cfg))
    err = np.abs(x - x_true).max() / np.abs(x_true).max()
    print(f"  {cfg.describe():38s} solve relerr = {err:.2e}")

# quantization saves badly-scaled systems (paper §III-D)
a_big = a * 1e6
l_q = np.asarray(cholesky(a_big, PrecisionConfig(
    levels=("f16", "f32"), leaf=128, quantize=True)))
l_n = np.asarray(cholesky(a_big, PrecisionConfig(
    levels=("f16", "f32"), leaf=128, quantize=False)))
print(f"\n||A||~1e9, f16 levels: quantize=True finite: "
      f"{np.isfinite(l_q).all()}, quantize=False finite: "
      f"{np.isfinite(l_n).all()}  (paper Fig. 3)")
