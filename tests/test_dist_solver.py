"""Distributed block-panel Cholesky on 8 host devices: exact vs the
single-device tree, both collective schedules, compressed collectives,
and the distributed solve. (Run via tests/test_multidevice.py.)"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.core as core
from repro.launch.mesh import make_mesh
from repro.core import distributed as dist

needs8 = pytest.mark.skipif(jax.device_count() < 8,
                            reason="needs 8 host devices")


def _setup(n=1024, seed=2):
    mesh = make_mesh((8,), ("model",))
    rng = np.random.default_rng(seed)
    m = rng.uniform(-1, 1, (n, n))
    a64 = m @ m.T + n * np.eye(n)
    a = jax.device_put(jnp.asarray(a64, jnp.float32),
                       NamedSharding(mesh, P("model", None)))
    return mesh, a, a64


@needs8
@pytest.mark.parametrize("bd", [True, False])
@pytest.mark.parametrize("cc", [True, False])
def test_dist_cholesky_schedules(bd, cc):
    mesh, a, a64 = _setup()
    cfg = core.PrecisionConfig(levels=("f32",), leaf=128)
    with mesh:
        l = dist.dist_cholesky(a, mesh, cfg, broadcast_diag_only=bd,
                               compress_comm=cc)
    want = np.linalg.cholesky(a64)
    rel = np.abs(np.asarray(l, np.float64) - want).max() / \
        np.abs(want).max()
    # compress_comm moves the panel in bf16 => bf16-level error
    tol = 5e-3 if cc else 5e-5
    assert rel < tol, (bd, cc, rel)


@needs8
def test_dist_cholesky_mixed_precision_matches_local():
    mesh, a, a64 = _setup()
    cfg = core.PrecisionConfig(levels=("f16", "f32"), leaf=128)
    with mesh:
        l = dist.dist_cholesky(a, mesh, cfg)
    want = np.linalg.cholesky(a64)
    rel = np.abs(np.asarray(l, np.float64) - want).max() / \
        np.abs(want).max()
    assert rel < 5e-3, rel


@needs8
def test_dist_solve():
    mesh, a, a64 = _setup(n=1024)
    cfg = core.PrecisionConfig(levels=("f32",), leaf=128)
    rng = np.random.default_rng(0)
    xt = rng.standard_normal((1024, 3))
    b = jax.device_put(jnp.asarray(a64 @ xt, jnp.float32),
                       NamedSharding(mesh, P("model", None)))
    with mesh:
        x = dist.dist_cholesky_solve(a, b, mesh, cfg)
    rel = np.abs(np.asarray(x, np.float64) - xt).max() / np.abs(xt).max()
    assert rel < 1e-4, rel
