"""Tests for the precision-conformance auditor (repro.audit)."""
import json

from repro.audit.report import (CheckResult, Violation, build_report,
                                load_report, validate_report)

CFG_KEY = "f16x3_f32"


def _cfg():
    from repro.core.precision import PAPER_CONFIGS
    return PAPER_CONFIGS[CFG_KEY]


# -- report schema ------------------------------------------------------

def test_report_roundtrip(tmp_path):
    res = CheckResult("demo", "t", [
        Violation("some-rule", "t", "boom", panel=1, tile=(2, 1)),
        Violation("other", "t", "meh", severity="warn")])
    assert not res.ok
    rep = build_report("smoke", [res])
    assert validate_report(rep) == []
    assert rep["summary"] == {"checks": 1, "violations": 2, "errors": 1,
                              "warns": 1}
    p = tmp_path / "r.json"
    p.write_text(json.dumps(rep))
    assert load_report(p)["summary"] == rep["summary"]


def test_validate_report_rejects_malformed():
    rep = build_report("smoke", [])
    del rep["summary"]
    assert validate_report(rep)
    assert validate_report({"schema": 999}) != []


# -- dtype-flow analysis ------------------------------------------------

def test_dtypeflow_tags_rounded_operands():
    import jax.numpy as jnp
    from repro.audit import dtypeflow

    def f(x):
        x16 = x.astype(jnp.float16).astype(jnp.float32)
        return x16 @ x16

    res = dtypeflow.trace(f, __import__("jax").ShapeDtypeStruct(
        (64, 64), jnp.float32))
    assert [d.eff_name for d in res.dots] == ["f16"]
    assert res.round_elems_by_name() == {"f16": 64 * 64}
    assert res.double_rounds() == []


def test_dtypeflow_flags_f16_bf16_double_round():
    import jax.numpy as jnp
    from repro.audit import dtypeflow

    def f(x):
        return x.astype(jnp.bfloat16).astype(
            jnp.float32).astype(jnp.float16).astype(jnp.float32)

    res = dtypeflow.trace(f, __import__("jax").ShapeDtypeStruct(
        (8, 8), jnp.float32))
    assert res.double_rounds()


# -- plan conformance ---------------------------------------------------

def test_audit_blocked_clean():
    from repro.audit.conformance import audit_blocked
    res = audit_blocked(512, _cfg())
    assert res.ok, [str(v) for v in res.violations]


def test_audit_blocked_names_flipped_tile():
    from repro.audit.conformance import audit_blocked
    from repro.core.plan import PrecisionPlan
    cfg = _cfg()
    mut = PrecisionPlan(512, cfg)
    mut.levels = mut.levels.copy()
    i, j = mut.ntiles - 1, mut.ntiles - 2
    mut.levels[i, j] = mut.levels[j, i] = (
        0 if mut.levels[i, j] else len(cfg.levels) - 1)
    res = audit_blocked(512, cfg, plan=mut)
    hits = [v for v in res.violations
            if v.rule in ("plan-table-mismatch", "plan-dot-precision")]
    assert hits and any(f"({i}, {j})" in str(v) for v in hits)


def test_audit_solve_clean():
    from repro.audit.conformance import audit_solve
    res = audit_solve(512, _cfg())
    assert res.ok, [str(v) for v in res.violations]


# -- kernel static checks -----------------------------------------------

def test_kernel_audit_clean():
    from repro.audit.kernelaudit import audit_kernels
    res = audit_kernels()
    assert res.ok, [str(v) for v in res.violations]


def test_kernel_audit_flags_narrow_accumulator_and_oob_map():
    import jax.numpy as jnp
    from jax.experimental import pallas as pl
    from repro.audit import kernelaudit

    class _Scratch:
        shape, dtype = (128, 128), jnp.bfloat16

    call = kernelaudit.KernelCall(
        name="_bad", entry="fake", grid=(2,),
        in_specs=(pl.BlockSpec((128, 128), lambda i: (i + 1, 0)),),
        out_specs=(pl.BlockSpec((128, 128), lambda i: (i, 0)),),
        scratch=(_Scratch(),),
        operands=(((256, 128), "float32"),),
        out_shapes=(((256, 128), "float32"),))
    viols = kernelaudit._index_violations(call, "t")
    assert any(v.rule == "kernel-index-bounds" for v in viols)
    res = kernelaudit.audit_kernels()          # real kernels stay clean
    assert res.ok


def test_kernel_audit_vmem_budget_trips():
    from repro.audit.kernelaudit import audit_kernels
    res = audit_kernels(vmem_budget=1024)      # absurdly small budget
    assert any(v.rule == "kernel-vmem-budget" for v in res.violations)


# -- lint pack ----------------------------------------------------------

def test_lint_repo_clean():
    """Regression for the literal sweep: kernels/ must stay free of
    hardcoded narrow dtypes and 65504, db.py jax-import-free (modulo the
    documented pragma), search.py timer-confined."""
    from repro.audit.lint import lint_repo
    res = lint_repo()
    assert res.ok, [str(v) for v in res.violations]


def test_lint_flags_planted_violations(tmp_path):
    src = tmp_path / "src" / "repro"
    (src / "core").mkdir(parents=True)
    (src / "tune").mkdir()
    (src / "kernels").mkdir()
    (src / "core" / "plan.py").write_text("import jax.numpy as jnp\n")
    (src / "tune" / "db.py").write_text(
        "def f():\n    from jax import devices\n    return devices\n")
    (src / "kernels" / "k.py").write_text(
        "import jax.numpy as jnp\n"
        "A = jnp.float16\n"
        "B = jnp.float32\n"          # wide: allowed
        "C = 65504.0\n")
    (src / "tune" / "search.py").write_text(
        "import time\n"
        "import numpy as np\n"
        "def timeit(fn):\n    return time.perf_counter()\n"
        "def bad():\n    return time.time(), np.random.default_rng()\n")
    from repro.audit.lint import lint_repo
    rules = sorted({v.rule for v in lint_repo(tmp_path).violations})
    assert rules == ["db-stdlib-only", "kernel-dtype-literal",
                     "plan-trace-free", "search-injected-timer"]
    by_rule = {}
    for v in lint_repo(tmp_path).violations:
        by_rule.setdefault(v.rule, []).append(v)
    # wide f32 literal not flagged; f16 + 65504 both are
    assert len(by_rule["kernel-dtype-literal"]) == 2
    # time.* inside timeit is allowed; time.time + unseeded rng outside not
    assert len(by_rule["search-injected-timer"]) == 2


def test_lint_pragma_suppresses(tmp_path):
    src = tmp_path / "src" / "repro"
    for d in ("core", "tune", "kernels"):
        (src / d).mkdir(parents=True)
    (src / "core" / "plan.py").write_text(
        "import jax  # audit: allow(plan-trace-free)\n")
    (src / "tune" / "db.py").write_text("")
    (src / "tune" / "search.py").write_text("")
    from repro.audit.lint import lint_repo
    assert lint_repo(tmp_path).ok


# -- mutation self-test (the full detection regression) -----------------

def test_selftest_catches_all_mutations():
    from repro.audit.selftest import run_selftest
    res = run_selftest()
    assert res.ok, [str(v) for v in res.violations]


# -- HLO reconciliation -------------------------------------------------

def test_hlo_single_reconciles_exactly():
    from repro.audit.hloaudit import audit_hlo_single
    res = audit_hlo_single(512, _cfg())
    errors = [v for v in res.violations if v.severity == "error"]
    assert not errors, [str(v) for v in errors]


def test_perf_gate_validates_audit_report(tmp_path):
    """tools/perf_gate.py audit — accepts a clean report, rejects one
    with errors and one with a wrong schema."""
    import subprocess
    import sys
    rep = build_report("smoke", [CheckResult("demo", "t", [])])
    good = tmp_path / "good.json"
    good.write_text(json.dumps(rep))
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(build_report(
        "smoke", [CheckResult("demo", "t",
                              [Violation("r", "t", "boom")])])))
    garbled = tmp_path / "garbled.json"
    garbled.write_text('{"schema": 999}')
    cmd = [sys.executable, "tools/perf_gate.py", "audit", "--json"]
    assert subprocess.run(cmd + [str(good)]).returncode == 0
    assert subprocess.run(cmd + [str(bad)]).returncode != 0
    assert subprocess.run(cmd + [str(garbled)]).returncode != 0
