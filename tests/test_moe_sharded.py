"""Sharded MoE (shard_map + ragged_dot EP) vs the dense dropless
reference, and a sharded train step vs its single-device twin.

These need 8 host devices; they skip under the default 1-device session
and are executed via tests/test_multidevice.py's subprocess runner."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe
from repro.launch.mesh import make_mesh
from repro.models.common import ModelConfig, Sharder

needs8 = pytest.mark.skipif(jax.device_count() < 8,
                            reason="needs 8 host devices")


def _mesh():
    return make_mesh((4, 2), ("data", "model"))


MOE_CFG = ModelConfig(
    name="moe-tiny", family="moe", n_layers=2, d_model=64, d_ff=128,
    vocab=128, n_heads=4, n_kv=4, mla=True, kv_lora=32, rope_head_dim=16,
    nope_head_dim=32, v_head_dim=32, moe_experts=8, moe_topk=2,
    moe_shared=1, moe_dff=96, moe_first_dense=0,
    moe_capacity_factor=16.0,   # dropless at this size
    max_seq=32)


@needs8
def test_sharded_moe_matches_dense_reference():
    mesh = _mesh()
    rng = jax.random.PRNGKey(0)
    p = moe.moe_params(rng, MOE_CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 64), jnp.float32)

    dense = moe.moe_ffn_dense_reference(x, p, MOE_CFG)

    sharder = Sharder(enabled=True, batch_axes=("data",),
                      model_axis="model", mesh=mesh)
    with mesh:
        routed, aux = jax.jit(
            lambda x, p: moe.moe_ffn(x, p, MOE_CFG, sharder))(x, p)
    # subtract the shared-expert part (reference covers routed only)
    sp = p["shared"]
    h = jnp.einsum("bsd,df->bsf", x, sp["w_in"])
    g = jnp.einsum("bsd,df->bsf", x, sp["w_gate"])
    shared = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * h, sp["w_out"])
    got = np.asarray(routed - shared)
    np.testing.assert_allclose(got, np.asarray(dense), rtol=2e-4,
                               atol=2e-4)
    assert np.isfinite(float(aux))


@needs8
def test_moe_capacity_drops_are_bounded():
    """With capacity_factor=1.0 some assignments drop, but outputs stay
    finite and close to dense for most tokens."""
    cfg = MOE_CFG.replace(moe_capacity_factor=1.0)
    mesh = _mesh()
    p = moe.moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 64), jnp.float32)
    sharder = Sharder(enabled=True, batch_axes=("data",),
                      model_axis="model", mesh=mesh)
    with mesh:
        routed, _ = jax.jit(
            lambda x, p: moe.moe_ffn(x, p, cfg, sharder))(x, p)
    assert np.isfinite(np.asarray(routed)).all()


@needs8
def test_sharded_train_step_matches_single_device():
    """The whole pjit train step under (4,2) mesh sharding rules must
    reproduce the unsharded step bit-for-bit-ish."""
    from repro.launch import sharding as SH
    from repro.optim import AdamWConfig
    from repro.train import TrainConfig, init_state, make_train_step

    cfg = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                      d_ff=128, vocab=128, n_heads=4, n_kv=2,
                      mlp="swiglu", max_seq=32, remat=False)
    tcfg = TrainConfig(adam=AdamWConfig(lr=1e-2, warmup=0,
                                        total_steps=10))
    rng = jax.random.PRNGKey(0)
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 128)
    batch = {"tokens": toks, "labels": toks}

    state0 = init_state(rng, cfg, tcfg)
    s_ref, m_ref = jax.jit(make_train_step(cfg, tcfg))(state0, batch)

    mesh = _mesh()
    sharder = SH.make_sharder(mesh, multi_pod=False, batch=8)
    with mesh:
        state0b = init_state(rng, cfg, tcfg)
        s_sh, m_sh = jax.jit(make_train_step(cfg, tcfg, sharder))(
            state0b, batch)
    assert abs(float(m_ref["loss"]) - float(m_sh["loss"])) < 1e-4
    d = max(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree.leaves(s_ref["params"]), jax.tree.leaves(s_sh["params"])))
    assert d < 1e-4, d
