"""Iterative-refinement tests: convergence of classic IR and GMRES-IR
across precision ladders (f64 reference under jax_enable_x64), the
zero-sweep no-op contract, the operator-level API used by K-FAC, and the
accuracy-targeted serve engine."""
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

import repro.core as core

RNG = np.random.default_rng(11)


def spd(n, dtype=np.float64, seed=0):
    rng = np.random.default_rng(seed)
    m = rng.uniform(-1, 1, (n, n))
    return ((m @ m.T + n * np.eye(n))).astype(dtype)


LADDERS = ["pure_f16", "f16_f32", "bf16_f32"]


@pytest.mark.parametrize("ladder", LADDERS)
@pytest.mark.parametrize("method", ["ir", "gmres"])
def test_refine_converges_x64(ladder, method):
    """Every cheap ladder must reach ~f64 working accuracy: residuals in
    f64, corrections through the low-precision factor."""
    with enable_x64():
        n = 512
        a = spd(n)
        b = a @ np.random.default_rng(1).standard_normal(n)
        rcfg = core.RefineConfig(max_sweeps=8, tol=1e-10, method=method,
                                 gmres_restart=8)
        res = core.refine_solve(a, b, core.PAPER_CONFIGS[ladder],
                                refine=rcfg)
        assert bool(res.converged), float(res.residual)
        assert float(res.residual) <= 1e-10
        relres = (np.linalg.norm(a @ np.asarray(res.x, np.float64) - b)
                  / np.linalg.norm(b))
        assert relres <= 5e-10, relres  # history matches true residual


def test_acceptance_f16_f32_5_sweeps():
    """ISSUE acceptance: 1024x1024 well-conditioned SPD, f16_f32 ladder,
    classic IR hits relative residual <= 1e-10 within 5 sweeps."""
    with enable_x64():
        n = 1024
        a = spd(n, seed=3)
        b = a @ np.random.default_rng(3).standard_normal(n)
        res = core.refine_solve(a, b, core.PAPER_CONFIGS["f16_f32"],
                                refine=core.RefineConfig(max_sweeps=5,
                                                         tol=1e-10))
        assert bool(res.converged)
        assert int(res.iterations) <= 5
        assert float(res.residual) <= 1e-10


def test_zero_sweeps_matches_plain_solve():
    n = 384
    a = spd(n, dtype=np.float32, seed=5)
    b = np.random.default_rng(5).standard_normal((n, 3)).astype(np.float32)
    cfg = core.PAPER_CONFIGS["f16_f32"]
    plain = np.asarray(core.cholesky_solve(a, b, cfg))
    res = core.refine_solve(a, b, cfg, refine=0)
    np.testing.assert_array_equal(np.asarray(res.x, np.float32), plain)
    # multi-RHS results are per-column: iterations has shape (k,)
    assert res.iterations.shape == (3,)
    assert (np.asarray(res.iterations) == 0).all()


def test_refine_result_contract():
    n = 256
    a = spd(n, dtype=np.float32, seed=7)
    b = (a @ np.random.default_rng(7).standard_normal(n)).astype(np.float32)
    rcfg = core.RefineConfig(max_sweeps=4, tol=1e-6)
    res = core.refine_solve(a, b, core.PAPER_CONFIGS["pure_f16"],
                            refine=rcfg)
    hist = np.asarray(res.history)
    k = int(res.iterations)
    assert hist.shape == (5,)
    assert np.isfinite(hist[:k + 1]).all()
    assert np.isnan(hist[k + 1:]).all()      # untaken sweeps stay nan
    assert float(res.residual) == np.nanmin(hist)   # best iterate wins
    assert hist[0] > float(res.residual)     # refinement helped
    assert res.x.shape == (n,)


def test_refine_never_degrades_past_floor():
    """At the f32 residual floor (x64 off) refinement stalls; the loop
    must return the BEST iterate and stop early (after two consecutive
    non-improving sweeps), not burn the whole sweep budget."""
    n = 512
    a = spd(n, dtype=np.float32, seed=23)
    b = (a @ np.random.default_rng(23).standard_normal(n)).astype(np.float32)
    # engine pinned: the stall mechanics under test live in _refine_loop
    # (engine-independent); on this seed the blocked engine's solves keep
    # eking out genuine sub-floor improvements and legitimately never
    # trigger the two-sweep stall within the budget.
    cfg = core.PrecisionConfig(levels=("f32",), leaf=128, engine="tree")
    res = core.refine_solve(a, b, cfg,
                            refine=core.RefineConfig(max_sweeps=8,
                                                     tol=1e-12))
    hist = np.asarray(res.history)
    base = hist[0]
    assert float(res.residual) <= base          # never worse than x0
    assert int(res.iterations) < 8              # stall detected early


def _ill_conditioned_spd(n, cond, seed):
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    a = (q * np.logspace(0, -np.log10(cond), n)) @ q.T
    return (a + a.T) / 2


def test_stall_tolerates_one_flat_sweep():
    """Regression: the loop used to abort after a SINGLE non-improving
    sweep, killing runs whose first sweep/restart is a flat transient.
    Ill-conditioned systems with a non-normal error in the approximate
    inverse (skewed stale preconditioners, GMRES-IR first restarts) do
    exactly this: the residual GROWS on sweep one, then collapses. Two
    consecutive non-improving sweeps are now required to exit."""
    with enable_x64():
        n = 64
        a = _ill_conditioned_spd(n, 1e6, seed=3)
        ainv = np.linalg.inv(a)
        # approximate inverse A^{-1}(I - N) with nilpotent skew
        # N = 2 e0 e1^T: the residual iteration is r -> N r, so for
        # r0 = e1 sweep 1 doubles the residual and sweep 2 lands exactly
        nmat = np.zeros((n, n))
        nmat[0, 1] = 2.0
        m = jnp.asarray(ainv @ (np.eye(n) - nmat))
        b = jnp.zeros(n).at[1].set(1.0)
        rcfg = core.RefineConfig(max_sweeps=4, tol=1e-8)
        res = core.refine_operator(lambda x: jnp.asarray(a) @ x,
                                   lambda r: m @ r, b, jnp.zeros(n), rcfg)
        hist = np.asarray(res.history)
        assert hist[1] >= hist[0]       # first sweep is non-improving...
        assert bool(res.converged)      # ...but the run must not abort
        assert int(res.iterations) == 2
        assert float(res.residual) <= 1e-8


def test_stall_exits_diverging_run_with_best_iterate():
    """A genuinely diverging iteration (residual doubling every sweep)
    must exit after exactly two non-improving sweeps with the best
    iterate — not burn max_sweeps."""
    with enable_x64():
        n = 64
        a = _ill_conditioned_spd(n, 1e4, seed=5)
        # A @ correct = -I, so r -> 2 r: divergence from sweep one
        m = jnp.asarray(np.linalg.inv(a) @ (-np.eye(n)))
        b = jnp.asarray(np.random.default_rng(5).standard_normal(n))
        x0 = jnp.zeros(n)
        rcfg = core.RefineConfig(max_sweeps=8, tol=1e-12)
        res = core.refine_operator(lambda x: jnp.asarray(a) @ x,
                                   lambda r: m @ r, b, x0, rcfg)
        assert int(res.iterations) == 2          # 2 flat sweeps, then out
        assert not bool(res.converged)
        assert float(res.residual) == np.asarray(res.history)[0]
        np.testing.assert_array_equal(np.asarray(res.x), np.asarray(x0))


def test_multi_rhs_per_column_convergence():
    """Columns with different per-column tolerances converge at
    different sweep counts; converged columns freeze (nan history)
    while slower neighbors keep sweeping."""
    with enable_x64():
        n = 256
        a = spd(n)
        b = a @ np.random.default_rng(2).standard_normal((n, 3))
        rcfg = core.RefineConfig(max_sweeps=8, tol=1e-11)
        col_tol = np.array([1e-2, 1e-6, 1e-11])
        res = core.refine_solve(a, b, core.PAPER_CONFIGS["bf16_f32"],
                                refine=rcfg, col_tol=jnp.asarray(col_tol))
        it = np.asarray(res.iterations)
        assert res.residual.shape == (3,) and it.shape == (3,)
        assert bool(np.asarray(res.converged).all())
        assert (np.asarray(res.residual) <= col_tol).all()
        assert it[0] <= it[1] <= it[2] and it[0] < it[2]
        hist = np.asarray(res.history)
        assert np.isnan(hist[it[0] + 1:, 0]).all()   # col 0 froze early
        assert np.isfinite(hist[:it[2] + 1, 2]).all()  # col 2 kept going
        x = np.asarray(res.x)
        for j in range(3):
            rr = (np.linalg.norm(a @ x[:, j] - b[:, j])
                  / np.linalg.norm(b[:, j]))
            assert rr <= col_tol[j] * 1.01, (j, rr)


def test_slow_steady_convergence_is_not_stalled():
    """A run that improves EVERY sweep — however slowly — must never be
    stalled out: stall needs two consecutive sweeps with no new best."""
    with enable_x64():
        n = 32
        # A = I, correct = 0.375 I  =>  r' = 0.625 r (a new best each sweep)
        b = jnp.asarray(np.random.default_rng(3).standard_normal(n))
        rcfg = core.RefineConfig(max_sweeps=20, tol=1e-4)
        res = core.refine_operator(lambda x: x, lambda r: 0.375 * r, b,
                                   jnp.zeros(n), rcfg)
        assert bool(res.converged), float(res.residual)
        assert int(res.iterations) == 20


def test_multi_rhs_scaled_solve_is_per_column():
    """Batched columns whose residual magnitudes differ by ~1e6 must
    each converge: a joint absmax scale would underflow the small column
    through the f16 correction path."""
    n = 256
    a = spd(n, dtype=np.float32, seed=33)
    rng = np.random.default_rng(33)
    b = np.stack([a @ rng.standard_normal(n),
                  1e6 * (a @ rng.standard_normal(n))],
                 axis=1).astype(np.float32)
    res = core.refine_solve(a, b, core.PAPER_CONFIGS["f16_f32"],
                            refine=core.RefineConfig(max_sweeps=8,
                                                     tol=1e-6))
    assert bool(np.asarray(res.converged).all()), np.asarray(res.residual)
    x = np.asarray(res.x, np.float64)
    for j in range(2):
        rr = (np.linalg.norm(a @ x[:, j] - b[:, j])
              / np.linalg.norm(b[:, j]))
        assert rr <= 2e-6, (j, rr)


def test_refine_keeps_residual_precision_for_narrow_rhs():
    """cholesky_solve(refine=) returns the residual-precision result: a
    bf16 RHS must NOT round-trip the refined solution back to bf16
    (which would throw away every digit refinement paid for)."""
    n = 256
    a = spd(n, dtype=np.float32, seed=29)
    xt = np.random.default_rng(29).standard_normal(n).astype(np.float32)
    b16 = jnp.asarray(a @ xt, jnp.bfloat16)
    cfg = core.PAPER_CONFIGS["bf16_f32"]
    x = core.cholesky_solve(a, b16, cfg, refine=4)
    assert x.dtype == jnp.float32            # residual precision, not bf16
    rr = (np.linalg.norm(a @ np.asarray(x, np.float64)
                         - np.asarray(b16, np.float64))
          / np.linalg.norm(np.asarray(b16, np.float64)))
    # bf16 eps is ~8e-3; the refined result must be far beyond that
    assert rr < 1e-5, rr


def test_cholesky_solve_refine_param():
    n = 256
    a = spd(n, dtype=np.float32, seed=9)
    b = (a @ np.random.default_rng(9).standard_normal(n)).astype(np.float32)
    cfg = core.PAPER_CONFIGS["bf16_f32"]
    x0 = np.asarray(core.cholesky_solve(a, b, cfg), np.float64)
    xr = np.asarray(core.cholesky_solve(a, b, cfg, refine=3), np.float64)
    r0 = np.linalg.norm(a @ x0 - b) / np.linalg.norm(b)
    rr = np.linalg.norm(a @ xr - b) / np.linalg.norm(b)
    assert rr < r0 / 10, (r0, rr)
    assert xr.shape == (n,) and core.cholesky_solve(
        a, b, cfg, refine=3).dtype == b.dtype


def test_refine_steps_operator():
    """The unrolled hot-path variant K-FAC uses: fixed sweeps against a
    deliberately stale preconditioner still contract the residual."""
    n = 128
    a = spd(n, dtype=np.float32, seed=13)
    stale = a + 0.05 * np.diag(np.abs(np.random.default_rng(13)
                                      .standard_normal(n))).astype(np.float32)
    l = np.linalg.cholesky(stale.astype(np.float64)).astype(np.float32)
    b = (a @ np.random.default_rng(14).standard_normal(n)).astype(np.float32)

    import scipy.linalg as sla

    def correct(r):
        y = sla.solve_triangular(l, np.asarray(r), lower=True)
        return jnp.asarray(sla.solve_triangular(l.T, y))

    matvec = lambda x: jnp.asarray(a) @ x  # noqa: E731
    x0 = correct(b)
    x = core.refine_steps(matvec, core.scaled_solve(correct),
                          jnp.asarray(b), x0, sweeps=4)
    r0 = np.linalg.norm(a @ np.asarray(x0) - b)
    r4 = np.linalg.norm(a @ np.asarray(x) - b)
    assert r4 < r0 / 50, (r0, r4)


def test_kfac_refine_sweeps_improves_whitening():
    """TreeNewtonConfig.refine_sweeps: IR against the CURRENT damped
    stats with a stale cached factor must steer the whitened direction
    toward the true Newton direction (A x ∝ g). Uses the identity
    factor K-FAC starts from — maximally stale — and also smokes the
    full jitted apply() path with refinement on."""
    import jax

    from repro.optim import kfac

    cfg = kfac.TreeNewtonConfig(block=128, refine_sweeps=3)
    cfg0 = kfac.TreeNewtonConfig(block=128, refine_sweeps=0)
    # stats drifted by several EMA steps since the factor was cached
    rng = np.random.default_rng(31)
    a_old = spd(128, dtype=np.float64, seed=31) / 128
    gg = rng.standard_normal((128, 256)) / 16
    a_new = 0.8 * a_old + 0.2 * (gg @ gg.T) / 256
    a_s = jnp.asarray(a_new, jnp.float32)[None]
    l_stale = jnp.asarray(np.linalg.cholesky(
        np.asarray(kfac._damped(jnp.asarray(a_old)[None], cfg))[0]),
        jnp.float32)[None]
    g = jnp.asarray(rng.standard_normal((128, 8)), jnp.float32)

    damped = np.asarray(kfac._damped(a_s, cfg))[0]

    def cos(x):
        ax = (damped @ np.asarray(x)).ravel()
        gf = np.asarray(g).ravel()
        return ax @ gf / (np.linalg.norm(ax) * np.linalg.norm(gf))

    x0 = kfac._whiten(g, l_stale, a_s, cfg0)
    x3 = kfac._whiten(g, l_stale, a_s, cfg)
    # angle error to the exact Newton direction shrinks >=10x
    assert 1 - cos(x3) < (1 - cos(x0)) / 10, (cos(x0), cos(x3))
    assert cos(x3) > 1 - 1e-6, cos(x3)

    params = {"mlp": {"w_in": jnp.zeros((128, 8))}}
    grads = {"mlp": {"w_in": g}}
    state = kfac.init(params, cfg)
    step = jax.jit(lambda gr, s, p: kfac.apply(gr, s, p, cfg))
    p1, s1, _ = step(grads, state, params)
    assert all(np.isfinite(x).all() for x in jax.tree.leaves(p1))


def test_gmres_beats_ir_when_factor_is_poor():
    """GMRES-IR tolerates a preconditioner too weak for classic IR."""
    with enable_x64():
        n = 256
        a = spd(n, seed=17)
        # degrade the preconditioner far beyond ladder quality
        noise = np.random.default_rng(17).standard_normal((n, n))
        m_bad = a + 0.35 * (noise @ noise.T) / n
        l = np.linalg.cholesky(m_bad)
        b = a @ np.random.default_rng(18).standard_normal(n)
        cfg = core.PrecisionConfig(levels=("f32",), leaf=128)
        kw = dict(max_sweeps=6, gmres_restart=10)
        ir = core.refine_solve(a, b, cfg, l=l,
                               refine=core.RefineConfig(tol=1e-10, **kw))
        gm = core.refine_solve(
            a, b, cfg, l=l, refine=core.RefineConfig(
                tol=1e-10, method="gmres", **kw))
        assert float(gm.residual) < float(ir.residual) / 10
        assert bool(gm.converged)


def test_solver_engine_targets():
    from repro.serve import SolverEngine
    n = 384
    a = spd(n, dtype=np.float32, seed=21)
    b = (a @ np.random.default_rng(21).standard_normal(n)).astype(np.float32)
    eng = SolverEngine("f16_f32", max_sweeps=8)
    x, info = eng.solve(a, b, target_digits=6.0, cache_key="k")
    assert info.converged and info.residual <= 1e-6
    assert not info.factor_cached
    _, info2 = eng.solve(a, b, target_digits=3.0, cache_key="k")
    assert info2.factor_cached and info2.sweeps <= info.sweeps
    # targets beyond the residual precision clamp instead of spinning
    _, info3 = eng.solve(a, b, target_digits=99.0, cache_key="k")
    assert info3.target_digits <= 14.0
    assert info3.sweeps <= 8
