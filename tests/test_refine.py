"""Iterative-refinement tests: convergence of classic IR and GMRES-IR
across precision ladders (f64 reference under jax_enable_x64), the
zero-sweep no-op contract, the operator-level API used by K-FAC, and the
accuracy-targeted serve engine."""
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

import repro.core as core

RNG = np.random.default_rng(11)


def spd(n, dtype=np.float64, seed=0):
    rng = np.random.default_rng(seed)
    m = rng.uniform(-1, 1, (n, n))
    return ((m @ m.T + n * np.eye(n))).astype(dtype)


LADDERS = ["pure_f16", "f16_f32", "bf16_f32"]


@pytest.mark.parametrize("ladder", LADDERS)
@pytest.mark.parametrize("method", ["ir", "gmres"])
def test_refine_converges_x64(ladder, method):
    """Every cheap ladder must reach ~f64 working accuracy: residuals in
    f64, corrections through the low-precision factor."""
    with enable_x64():
        n = 512
        a = spd(n)
        b = a @ np.random.default_rng(1).standard_normal(n)
        rcfg = core.RefineConfig(max_sweeps=8, tol=1e-10, method=method,
                                 gmres_restart=8)
        res = core.refine_solve(a, b, core.PAPER_CONFIGS[ladder],
                                refine=rcfg)
        assert bool(res.converged), float(res.residual)
        assert float(res.residual) <= 1e-10
        relres = (np.linalg.norm(a @ np.asarray(res.x, np.float64) - b)
                  / np.linalg.norm(b))
        assert relres <= 5e-10, relres  # history matches true residual


def test_acceptance_f16_f32_5_sweeps():
    """ISSUE acceptance: 1024x1024 well-conditioned SPD, f16_f32 ladder,
    classic IR hits relative residual <= 1e-10 within 5 sweeps."""
    with enable_x64():
        n = 1024
        a = spd(n, seed=3)
        b = a @ np.random.default_rng(3).standard_normal(n)
        res = core.refine_solve(a, b, core.PAPER_CONFIGS["f16_f32"],
                                refine=core.RefineConfig(max_sweeps=5,
                                                         tol=1e-10))
        assert bool(res.converged)
        assert int(res.iterations) <= 5
        assert float(res.residual) <= 1e-10


def test_zero_sweeps_matches_plain_solve():
    n = 384
    a = spd(n, dtype=np.float32, seed=5)
    b = np.random.default_rng(5).standard_normal((n, 3)).astype(np.float32)
    cfg = core.PAPER_CONFIGS["f16_f32"]
    plain = np.asarray(core.cholesky_solve(a, b, cfg))
    res = core.refine_solve(a, b, cfg, refine=0)
    np.testing.assert_array_equal(np.asarray(res.x, np.float32), plain)
    assert int(res.iterations) == 0


def test_refine_result_contract():
    n = 256
    a = spd(n, dtype=np.float32, seed=7)
    b = (a @ np.random.default_rng(7).standard_normal(n)).astype(np.float32)
    rcfg = core.RefineConfig(max_sweeps=4, tol=1e-6)
    res = core.refine_solve(a, b, core.PAPER_CONFIGS["pure_f16"],
                            refine=rcfg)
    hist = np.asarray(res.history)
    k = int(res.iterations)
    assert hist.shape == (5,)
    assert np.isfinite(hist[:k + 1]).all()
    assert np.isnan(hist[k + 1:]).all()      # untaken sweeps stay nan
    assert float(res.residual) == np.nanmin(hist)   # best iterate wins
    assert hist[0] > float(res.residual)     # refinement helped
    assert res.x.shape == (n,)


def test_refine_never_degrades_past_floor():
    """At the f32 residual floor (x64 off) refinement stalls; the loop
    must return the BEST iterate and stop early, not the last one."""
    n = 512
    a = spd(n, dtype=np.float32, seed=23)
    b = (a @ np.random.default_rng(23).standard_normal(n)).astype(np.float32)
    cfg = core.PrecisionConfig(levels=("f32",), leaf=128)
    res = core.refine_solve(a, b, cfg,
                            refine=core.RefineConfig(max_sweeps=5,
                                                     tol=1e-12))
    hist = np.asarray(res.history)
    base = hist[0]
    assert float(res.residual) <= base          # never worse than x0
    assert int(res.iterations) < 5              # stall detected early


def test_cholesky_solve_refine_param():
    n = 256
    a = spd(n, dtype=np.float32, seed=9)
    b = (a @ np.random.default_rng(9).standard_normal(n)).astype(np.float32)
    cfg = core.PAPER_CONFIGS["bf16_f32"]
    x0 = np.asarray(core.cholesky_solve(a, b, cfg), np.float64)
    xr = np.asarray(core.cholesky_solve(a, b, cfg, refine=3), np.float64)
    r0 = np.linalg.norm(a @ x0 - b) / np.linalg.norm(b)
    rr = np.linalg.norm(a @ xr - b) / np.linalg.norm(b)
    assert rr < r0 / 10, (r0, rr)
    assert xr.shape == (n,) and core.cholesky_solve(
        a, b, cfg, refine=3).dtype == b.dtype


def test_refine_steps_operator():
    """The unrolled hot-path variant K-FAC uses: fixed sweeps against a
    deliberately stale preconditioner still contract the residual."""
    n = 128
    a = spd(n, dtype=np.float32, seed=13)
    stale = a + 0.05 * np.diag(np.abs(np.random.default_rng(13)
                                      .standard_normal(n))).astype(np.float32)
    l = np.linalg.cholesky(stale.astype(np.float64)).astype(np.float32)
    b = (a @ np.random.default_rng(14).standard_normal(n)).astype(np.float32)

    import scipy.linalg as sla

    def correct(r):
        y = sla.solve_triangular(l, np.asarray(r), lower=True)
        return jnp.asarray(sla.solve_triangular(l.T, y))

    matvec = lambda x: jnp.asarray(a) @ x  # noqa: E731
    x0 = correct(b)
    x = core.refine_steps(matvec, core.scaled_solve(correct),
                          jnp.asarray(b), x0, sweeps=4)
    r0 = np.linalg.norm(a @ np.asarray(x0) - b)
    r4 = np.linalg.norm(a @ np.asarray(x) - b)
    assert r4 < r0 / 50, (r0, r4)


def test_kfac_refine_sweeps_improves_whitening():
    """TreeNewtonConfig.refine_sweeps: IR against the CURRENT damped
    stats with a stale cached factor must steer the whitened direction
    toward the true Newton direction (A x ∝ g). Uses the identity
    factor K-FAC starts from — maximally stale — and also smokes the
    full jitted apply() path with refinement on."""
    import jax

    from repro.optim import kfac

    cfg = kfac.TreeNewtonConfig(block=128, refine_sweeps=3)
    cfg0 = kfac.TreeNewtonConfig(block=128, refine_sweeps=0)
    # stats drifted by several EMA steps since the factor was cached
    rng = np.random.default_rng(31)
    a_old = spd(128, dtype=np.float64, seed=31) / 128
    gg = rng.standard_normal((128, 256)) / 16
    a_new = 0.8 * a_old + 0.2 * (gg @ gg.T) / 256
    a_s = jnp.asarray(a_new, jnp.float32)[None]
    l_stale = jnp.asarray(np.linalg.cholesky(
        np.asarray(kfac._damped(jnp.asarray(a_old)[None], cfg))[0]),
        jnp.float32)[None]
    g = jnp.asarray(rng.standard_normal((128, 8)), jnp.float32)

    damped = np.asarray(kfac._damped(a_s, cfg))[0]

    def cos(x):
        ax = (damped @ np.asarray(x)).ravel()
        gf = np.asarray(g).ravel()
        return ax @ gf / (np.linalg.norm(ax) * np.linalg.norm(gf))

    x0 = kfac._whiten(g, l_stale, a_s, cfg0)
    x3 = kfac._whiten(g, l_stale, a_s, cfg)
    # angle error to the exact Newton direction shrinks >=10x
    assert 1 - cos(x3) < (1 - cos(x0)) / 10, (cos(x0), cos(x3))
    assert cos(x3) > 1 - 1e-6, cos(x3)

    params = {"mlp": {"w_in": jnp.zeros((128, 8))}}
    grads = {"mlp": {"w_in": g}}
    state = kfac.init(params, cfg)
    step = jax.jit(lambda gr, s, p: kfac.apply(gr, s, p, cfg))
    p1, s1, _ = step(grads, state, params)
    assert all(np.isfinite(x).all() for x in jax.tree.leaves(p1))


def test_gmres_beats_ir_when_factor_is_poor():
    """GMRES-IR tolerates a preconditioner too weak for classic IR."""
    with enable_x64():
        n = 256
        a = spd(n, seed=17)
        # degrade the preconditioner far beyond ladder quality
        noise = np.random.default_rng(17).standard_normal((n, n))
        m_bad = a + 0.35 * (noise @ noise.T) / n
        l = np.linalg.cholesky(m_bad)
        b = a @ np.random.default_rng(18).standard_normal(n)
        cfg = core.PrecisionConfig(levels=("f32",), leaf=128)
        kw = dict(max_sweeps=6, gmres_restart=10)
        ir = core.refine_solve(a, b, cfg, l=l,
                               refine=core.RefineConfig(tol=1e-10, **kw))
        gm = core.refine_solve(
            a, b, cfg, l=l, refine=core.RefineConfig(
                tol=1e-10, method="gmres", **kw))
        assert float(gm.residual) < float(ir.residual) / 10
        assert bool(gm.converged)


def test_solver_engine_targets():
    from repro.serve import SolverEngine
    n = 384
    a = spd(n, dtype=np.float32, seed=21)
    b = (a @ np.random.default_rng(21).standard_normal(n)).astype(np.float32)
    eng = SolverEngine("f16_f32", max_sweeps=8)
    x, info = eng.solve(a, b, target_digits=6.0, cache_key="k")
    assert info.converged and info.residual <= 1e-6
    assert not info.factor_cached
    _, info2 = eng.solve(a, b, target_digits=3.0, cache_key="k")
    assert info2.factor_cached and info2.sweeps <= info.sweeps
    # targets beyond the residual precision clamp instead of spinning
    _, info3 = eng.solve(a, b, target_digits=99.0, cache_key="k")
    assert info3.target_digits <= 14.0
    assert info3.sweeps <= 8
