"""Tuning-database behavior suite (repro.tune, docs/TUNING.md).

Covers the lookup relaxation chain (exact -> crossover -> nearest ->
defaults), the corrupt/missing-database fallbacks, the SolverEngine /
scheduler consultation points, and the autotuner's determinism under an
injected timer. Everything runs on whatever devices the session has —
the distributed knobs are exercised through a 1-wide mesh.
"""
from __future__ import annotations

import dataclasses
import json
import warnings

import numpy as np
import pytest

from repro import tune
from repro.core.precision import PrecisionConfig
from repro.tune.db import DEFAULTS, TunedDecision, TuningDB
from repro.tune.search import interp_crossover


# ---------------------------------------------------------------------------
# payload builders
# ---------------------------------------------------------------------------
def entry(n, ladder="bf16_f32", nshards=1, **choice):
    choice.setdefault("engine", "tree")
    return {"backend": "cpu", "n": n, "ladder": ladder, "nshards": nshards,
            "choice": choice, "measurements": {"us_probe": 1.0}}


def payload(entries, crossovers=()):
    return {"version": 1, "backend": "cpu", "smoke": True,
            "sizes": [e["n"] for e in entries],
            "nshards_dist": None, "entries": entries,
            "crossovers": list(crossovers)}


def xover(n, ladder="bf16_f32", nshards=1):
    return {"backend": "cpu", "ladder": ladder, "nshards": nshards,
            "knob": "engine", "below": "tree", "above": "blocked", "n": n}


# ---------------------------------------------------------------------------
# lookup relaxation chain
# ---------------------------------------------------------------------------
def test_exact_hit_wins():
    db = TuningDB(payload(
        [entry(512, engine="tree", leaf=128, max_batch=8),
         entry(2048, engine="blocked", leaf=256)],
        [xover(1200)]))
    d = db.decide(512, "bf16_f32", 1)
    assert (d.source, d.engine, d.leaf, d.max_batch) == \
        ("exact", "tree", 128, 8)
    assert d.matched_n == 512
    # un-set knobs in the choice come from DEFAULTS
    assert d.dist_threshold == DEFAULTS["dist_threshold"]


def test_crossover_resolves_unmeasured_sizes():
    db = TuningDB(payload(
        [entry(512, engine="tree", leaf=128),
         entry(2048, engine="blocked", leaf=256)],
        [xover(1200)]))
    below = db.decide(1024, "bf16_f32", 1)
    above = db.decide(1536, "bf16_f32", 1)
    assert below.source == above.source == "crossover"
    assert below.engine == "tree"
    assert above.engine == "blocked"
    # non-engine knobs come from the nearest-n entry (log-space)
    assert below.matched_n == 512 and below.leaf == 128
    assert above.matched_n == 2048 and above.leaf == 256


def test_null_crossover_means_tree_everywhere():
    db = TuningDB(payload([entry(512, engine="tree")], [xover(None)]))
    assert db.decide(1 << 20, "bf16_f32", 1).engine == "tree"


def test_nearest_key_fallbacks():
    db = TuningDB(payload(
        [entry(512, ladder="bf16_f32", engine="tree", max_batch=16)]))
    # same ladder, no crossover record -> nearest-n entry
    d = db.decide(4096, "bf16_f32", 1)
    assert (d.source, d.engine, d.max_batch) == ("nearest", "tree", 16)
    # unknown ladder -> nearest entry for the same nshards, any ladder
    d = db.decide(512, "f16_f32", 1)
    assert (d.source, d.max_batch) == ("nearest", 16)
    # unknown nshards -> defaults
    d = db.decide(512, "bf16_f32", 8)
    assert d.source == "default"
    assert d == TunedDecision.defaults()


def test_module_decide_with_injected_db():
    db = TuningDB(payload([entry(256, engine="blocked", leaf=256)]))
    assert tune.decide(256, "bf16_f32", db=db).engine == "blocked"


# ---------------------------------------------------------------------------
# corrupt / missing databases
# ---------------------------------------------------------------------------
def test_corrupt_db_warns_and_defaults(tmp_path, monkeypatch):
    bad = tmp_path / "bad.json"
    bad.write_text("{not json", encoding="utf-8")
    monkeypatch.setenv(tune.db.ENV_DB, str(bad))
    tune.clear_cache()
    try:
        with pytest.warns(UserWarning, match="corrupt tuning DB"):
            d = tune.decide(1024, "bf16_f32", backend="cpu")
        assert d == TunedDecision.defaults()
    finally:
        tune.clear_cache()


def test_invalid_schema_warns_and_defaults(tmp_path, monkeypatch):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"version": 1}), encoding="utf-8")
    monkeypatch.setenv(tune.db.ENV_DB, str(bad))
    tune.clear_cache()
    try:
        with pytest.warns(UserWarning, match="corrupt tuning DB"):
            d = tune.decide(1024, "bf16_f32", backend="cpu")
        assert d == TunedDecision.defaults()
    finally:
        tune.clear_cache()


def test_missing_explicit_db_warns_and_defaults(tmp_path, monkeypatch):
    monkeypatch.setenv(tune.db.ENV_DB, str(tmp_path / "nope.json"))
    tune.clear_cache()
    try:
        with pytest.warns(UserWarning, match="not found"):
            d = tune.decide(1024, "bf16_f32", backend="cpu")
        assert d == TunedDecision.defaults()
    finally:
        tune.clear_cache()


def test_missing_packaged_db_is_silent():
    # a backend with no committed database is the normal untuned state
    tune.clear_cache()
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            d = tune.decide(1024, "bf16_f32", backend="no_such_backend")
        assert d.source == "default"
    finally:
        tune.clear_cache()


def test_validate_db_catches_breakage():
    good = payload([entry(512)], [xover(1200)])
    assert tune.validate_db(good) == []
    assert tune.validate_db([]) != []
    assert tune.validate_db({}) != []
    no_engine = payload([{**entry(512), "choice": {"leaf": 128}}])
    assert any("choice.engine" in e for e in tune.validate_db(no_engine))
    bad_t = payload([entry(512)])
    bad_t["entries"][0]["measurements"] = {"us_probe": float("nan")}
    assert any("finite" in e for e in tune.validate_db(bad_t))
    bad_x = payload([entry(512)], [{**xover(1200), "n": -3}])
    assert any("crossovers[0]" in e for e in tune.validate_db(bad_x))
    with pytest.raises(ValueError):
        TuningDB({})


def test_verify_consultation_flags_mismatch():
    ok = TuningDB(payload(
        [entry(512, engine="tree"), entry(2048, engine="blocked")],
        [xover(1200)]))
    assert tune.verify_consultation(ok) == []
    # a database whose entries contradict its crossover fails
    lying = TuningDB(payload(
        [entry(512, engine="blocked"), entry(2048, engine="blocked")],
        [xover(None)]))
    assert tune.verify_consultation(lying) != []


# ---------------------------------------------------------------------------
# consumers: resolve_cfg, SolverEngine, BatchScheduler
# ---------------------------------------------------------------------------
def test_resolve_cfg_only_touches_auto():
    db = TuningDB(payload([entry(512, engine="tree", leaf=128)]))
    explicit = PrecisionConfig(levels=("bf16", "f32"), engine="blocked")
    assert tune.resolve_cfg(explicit, 512, db=db) is explicit
    auto = dataclasses.replace(explicit, engine="auto")
    got = tune.resolve_cfg(auto, 512, db=db)
    assert got.engine == "tree"
    assert got.leaf == auto.leaf      # plan geometry never changes


def test_auto_engine_solves_correctly():
    from repro.core.solve import cholesky_solve
    rng = np.random.default_rng(0)
    n = 192                           # non-multiple-of-leaf on purpose
    m = rng.uniform(-1, 1, (n, n))
    a = ((m + m.T) / 2 + n * np.eye(n)).astype(np.float32)
    b = rng.standard_normal(n).astype(np.float32)
    cfg = PrecisionConfig(levels=("bf16", "f32"), leaf=128, engine="auto")
    x = np.asarray(cholesky_solve(a, b, cfg))
    rel = np.linalg.norm(a @ x - b) / np.linalg.norm(b)
    assert rel < 5e-2                 # bf16 factor, no refinement


def test_solver_engine_routes_on_tuned_dist_threshold():
    from repro.launch.mesh import make_mesh
    from repro.serve.engine import SolverEngine
    mesh = make_mesh((1,), ("model",))
    rng = np.random.default_rng(1)
    n = 256
    m = rng.uniform(-1, 1, (n, n))
    a = ((m + m.T) / 2 + n * np.eye(n)).astype(np.float32)
    b = rng.standard_normal(n).astype(np.float32)
    lo = TuningDB(payload([entry(n, dist_threshold=256)]))
    hi = TuningDB(payload([entry(n, dist_threshold=1024)]))
    for db, want in ((lo, True), (hi, False)):
        eng = SolverEngine(PrecisionConfig(levels=("bf16", "f32"),
                                           leaf=128),
                           mesh=mesh, tuning_db=db)
        assert eng.dist_threshold is None     # = consult the database
        x, info = eng.solve(a, b, target_digits=5)
        assert info.distributed is want, (want, info)
        rel = np.linalg.norm(a @ np.asarray(x) - b) / np.linalg.norm(b)
        assert rel < 1e-4
    # an explicit constructor threshold pins the routing, DB ignored
    eng = SolverEngine(PrecisionConfig(levels=("bf16", "f32"), leaf=128),
                       mesh=mesh, dist_threshold=10 ** 9, tuning_db=lo)
    _, info = eng.solve(a, b, target_digits=5)
    assert info.distributed is False


def test_scheduler_max_batch_consults_db():
    from repro.serve.engine import SolverEngine
    from repro.serve.scheduler import BatchScheduler
    db = TuningDB(payload([entry(256, max_batch=8)]))
    eng = SolverEngine(PrecisionConfig(levels=("bf16", "f32"), leaf=128),
                       tuning_db=db)
    assert BatchScheduler(eng).max_batch == 8
    assert BatchScheduler(eng, max_batch=4).max_batch == 4  # explicit wins
    # no engine / no database entry -> the pre-tuner default geometry
    assert BatchScheduler(
        SolverEngine(tuning_db=TuningDB(payload([entry(99999)])))
    ).max_batch == DEFAULTS["max_batch"]


# ---------------------------------------------------------------------------
# the search itself
# ---------------------------------------------------------------------------
def test_interp_crossover():
    # blocked must clear the REL_TOL noise margin to win a grid point
    assert interp_crossover([512, 1024], [100, 100], [90, 90]) == 512
    assert interp_crossover([512, 1024], [100, 100], [101, 99.9]) is None
    mid = interp_crossover([1024, 2048], [100.0, 120.0], [110.0, 80.0])
    assert 1024 < mid <= 2048
    # a sub-noise "win" at the flip point does not move the crossover
    tie = interp_crossover([1024, 2048], [100.0, 120.0], [99.9, 80.0])
    assert 1024 < tie <= 2048
    # non-monotone grid: an isolated blocked win at the smallest size is
    # noise when the tree owns every larger size — tree everywhere
    assert interp_crossover([512, 1024, 2048], [100.0, 100.0, 100.0],
                            [90.0, 105.0, 105.0]) is None


def test_refit_engines_follows_crossover():
    from repro.tune.search import _refit_engines
    entries = [
        {"ladder": "bf16_f32", "nshards": 1, "n": 512,
         "choice": {"engine": "blocked", "leaf": 128},
         "measurements": {"us_tree_leaf128": 100.0, "us_tree_leaf256": 95.0,
                          "us_blocked_leaf128": 90.0,
                          "us_blocked_leaf256": 96.0}},
        {"ladder": "bf16_f32", "nshards": 1, "n": 2048,
         "choice": {"engine": "tree", "leaf": 256},
         "measurements": {"us_tree_leaf256": 100.0,
                          "us_blocked_leaf256": 90.0}},
        {"ladder": "bf16_f32", "nshards": 4, "n": 512,
         "choice": {"engine": "blocked", "leaf": 128},
         "measurements": {"us_local_tree": 100.0,
                          "us_local_blocked": 90.0}},
    ]
    # fitted crossover says tree below 1024: the noisy 512 blocked vote is
    # overridden (and the leaf re-picked for the tree race), the 2048
    # entry flips to blocked, the other-nshards entry is untouched
    _refit_engines(entries, "bf16_f32", 1, 1024)
    assert entries[0]["choice"] == {"engine": "tree", "leaf": 256}
    assert entries[1]["choice"] == {"engine": "blocked", "leaf": 256}
    assert entries[2]["choice"]["engine"] == "blocked"
    # xn=None means the tree owns the whole grid
    _refit_engines(entries, "bf16_f32", 4, None)
    assert entries[2]["choice"]["engine"] == "tree"


def test_autotune_deterministic_and_valid():
    calls = [0]

    def fake_timer(fn, *args):
        calls[0] += 1
        return 1000.0 + 7.0 * calls[0]    # fixed, order-dependent

    def quiet(name, us, derived):
        pass

    def run():
        calls[0] = 0
        return tune.autotune("cpu", smoke=True, timer=fake_timer,
                             log=quiet, nshards=0, serving=True)

    p1, p2 = run(), run()
    assert json.dumps(p1, sort_keys=True) == json.dumps(p2, sort_keys=True)
    assert tune.validate_db(p1) == []
    db = TuningDB(p1)
    # strictly increasing fake times -> earlier candidates win -> the
    # noise-margined pick is the tree engine at every smoke size
    for e in p1["entries"]:
        assert e["choice"]["engine"] == "tree"
    assert tune.verify_consultation(db) == []
