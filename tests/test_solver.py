"""Core solver tests: correctness, precision-ladder properties (paper
Fig. 8 ordering), quantization invariants — including hypothesis
property-based tests on the system's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # optional dep (pip install -e .[test] brings it)
    # Shim so only the property tests skip; a module-level
    # pytest.importorskip would skip the whole file.
    _SKIP = pytest.mark.skip(reason="hypothesis not installed")

    def given(**_kw):
        return lambda _f: _SKIP(_f)

    def settings(**_kw):
        return lambda f: f

    class st:  # noqa: N801 - mirrors `hypothesis.strategies as st`
        integers = staticmethod(lambda *a, **k: None)
        floats = staticmethod(lambda *a, **k: None)

import repro.core as core

RNG = np.random.default_rng(7)


def spd(n, dtype=np.float32, scale=1.0, seed=None):
    rng = np.random.default_rng(seed) if seed is not None else RNG
    m = rng.uniform(-1, 1, (n, n))
    a = (m @ m.T + n * np.eye(n)) * scale
    return a.astype(dtype)


F32 = core.PrecisionConfig(levels=("f32",), leaf=128)


@pytest.mark.parametrize("n", [64, 128, 256, 300, 512, 1000])
def test_potrf_matches_lapack(n):
    a = spd(n)
    l = np.asarray(core.cholesky(a, F32), np.float64)
    ref = np.linalg.cholesky(a.astype(np.float64))
    rel = np.abs(l - ref).max() / np.abs(ref).max()
    assert rel < 5e-5, rel


@pytest.mark.parametrize("leaf", [128, 256, 512])
def test_leaf_size_invariance(leaf):
    a = spd(1024)
    cfg = core.PrecisionConfig(levels=("f32",), leaf=leaf)
    l = np.asarray(core.cholesky(a, cfg), np.float64)
    ref = np.linalg.cholesky(a.astype(np.float64))
    assert np.abs(l - ref).max() / np.abs(ref).max() < 5e-5


@pytest.mark.parametrize("nrhs", [1, 3, 64])
def test_solve(nrhs):
    n = 640
    a = spd(n)
    x_true = RNG.standard_normal((n, nrhs)).astype(np.float32)
    b = a @ x_true
    x = np.asarray(core.cholesky_solve(a, b, F32))
    assert np.abs(x - x_true).max() / np.abs(x_true).max() < 1e-4


def test_solve_vector_shape():
    n = 256
    a = spd(n)
    b = RNG.standard_normal(n).astype(np.float32)
    x = core.cholesky_solve(a, b, F32)
    assert x.shape == (n,)
    assert np.abs(np.asarray(a @ x - b)).max() < 1e-2


def test_precision_ladder_ordering():
    """Paper Fig. 8: accuracy must degrade monotonically (within noise)
    as more recursion levels drop to f16, and every mixed config must
    beat pure f16."""
    a = spd(1024, seed=3)
    ref = np.linalg.cholesky(a.astype(np.float64))

    def err(levels):
        cfg = core.PrecisionConfig(levels=levels, leaf=128)
        l = np.asarray(core.cholesky(a, cfg), np.float64)
        return np.abs(l - ref).max() / np.abs(ref).max()

    e_f32 = err(("f32",))
    e_1 = err(("f16", "f32"))
    e_3 = err(("f16", "f16", "f16", "f32"))
    e_f16 = err(("f16",))
    assert e_f32 < e_1 < e_3 * 1.5
    assert e_3 <= e_f16 * 1.5
    assert e_1 < e_f16 / 5, (e_1, e_f16)


def test_int8_ladder_level():
    """Beyond-paper int8 level: always-scaled per-block quantization on
    the integer MXU path. ~3 digits, finite, and the factor reconstructs
    to int8-grid tolerance."""
    a = spd(1024, seed=9)
    ref = np.linalg.cholesky(a.astype(np.float64))
    cfg = core.PrecisionConfig(levels=("int8", "f32"), leaf=128)
    l = np.asarray(core.cholesky(a, cfg), np.float64)
    assert np.isfinite(l).all()
    err = np.linalg.norm(l - ref) / np.linalg.norm(ref)
    assert err < 5e-3, err          # >= ~2.3 digits
    # int8 quant roundtrip invariant
    xq, alpha = core.quant_block(jnp.asarray(a[:64, :64]), "int8", True)
    back = np.asarray(xq, np.float64) * float(alpha)
    assert np.abs(back - a[:64, :64]).max() <= float(alpha) * 0.5 + 1e-6


def test_quantization_prevents_overflow():
    a = spd(512, scale=1e6, seed=4)
    cfg_q = core.PrecisionConfig(levels=("f16", "f32"), leaf=128,
                                 quantize=True)
    cfg_n = core.PrecisionConfig(levels=("f16", "f32"), leaf=128,
                                 quantize=False)
    lq = np.asarray(core.cholesky(a, cfg_q))
    ln = np.asarray(core.cholesky(a, cfg_n))
    assert np.isfinite(lq).all()
    assert not np.isfinite(ln).all()   # overflow without the paper's fix
    ref = np.linalg.cholesky(a.astype(np.float64))
    assert np.abs(lq - ref).max() / np.abs(ref).max() < 1e-3


def test_tree_syrk_vs_dense():
    n, k = 512, 320
    c = RNG.standard_normal((n, n)).astype(np.float32)
    a = RNG.standard_normal((n, k)).astype(np.float32)
    got = np.asarray(core.tree_syrk(jnp.asarray(c), jnp.asarray(a),
                                    alpha=-2.0, beta=0.5, cfg=F32))
    want = np.tril(0.5 * c - 2.0 * (a @ a.T))
    np.testing.assert_allclose(np.tril(got), want, rtol=1e-4, atol=1e-3)


def test_tree_trsm_vs_scipy():
    import scipy.linalg as sla
    n, m = 512, 384
    l = np.tril(RNG.standard_normal((n, n))).astype(np.float32)
    l[np.diag_indices(n)] += np.sqrt(n) * 4
    b = RNG.standard_normal((m, n)).astype(np.float32)
    got = np.asarray(core.tree_trsm(jnp.asarray(b), jnp.asarray(l), F32))
    want = sla.solve_triangular(l.astype(np.float64),
                                b.T.astype(np.float64), lower=True).T
    assert np.abs(got - want).max() / np.abs(want).max() < 1e-4


# ---------------------------------------------------------------------------
# hypothesis property tests
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 12), scale=st.floats(1e-3, 1e3),
       seed=st.integers(0, 2**31 - 1))
def test_property_factor_reconstructs(n, scale, seed):
    """L L^T == A for any well-conditioned SPD input, any size (padding
    path included), any scale."""
    n = n * 32  # 64..384, exercises pad + leaf paths
    a = spd(n, scale=scale, seed=seed)
    l = np.asarray(core.cholesky(a, F32), np.float64)
    rec = l @ l.T
    rel = np.abs(rec - a).max() / np.abs(a).max()
    assert rel < 1e-4, rel
    # lower-triangularity invariant
    assert np.abs(np.triu(l, 1)).max() == 0.0


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       exp=st.integers(-6, 6))
def test_property_quantization_roundtrip(seed, exp):
    """quant/dequant is a contraction: |deq(q(x)) - x| <= f16 eps * alpha
    and alpha >= 1 with equality iff in range."""
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((64, 64)) * 10.0 ** exp).astype(np.float32)
    xq, alpha = core.quant_block(jnp.asarray(x), "f16", True)
    back = np.asarray(xq, np.float32) * float(alpha)
    amax = np.abs(x).max()
    assert float(alpha) >= 1.0
    if amax <= 65504:
        assert float(alpha) == 1.0
    tol = max(amax, 1.0) * 1e-3
    assert np.abs(back - x).max() <= tol
    assert np.isfinite(np.asarray(xq, np.float32)).all()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_solve_residual(seed):
    """||A x - b|| / ||b|| small for the mixed bf16 ladder (the TPU
    default) on random SPD systems."""
    rng = np.random.default_rng(seed)
    n = 256
    a = spd(n, seed=seed)
    b = rng.standard_normal((n, 2)).astype(np.float32)
    cfg = core.PrecisionConfig(levels=("bf16", "f32"), leaf=128)
    x = np.asarray(core.cholesky_solve(a, b, cfg), np.float64)
    res = np.abs(a @ x - b).max() / np.abs(b).max()
    assert res < 5e-2, res


def test_census_flop_exactness():
    """Census total must equal n^3/3 + O(n^2) for any leaf/level mix."""
    for n in (1024, 4096):
        for cfg in (F32, core.PrecisionConfig(levels=("f16",) * 3 + ("f32",),
                                              leaf=256)):
            cen = core.census_potrf(n, cfg)
            assert abs(cen.total_flops - n ** 3 / 3) / (n ** 3 / 3) < 0.02


def test_census_depth_monotone():
    """Deeper recursion (bigger n) => higher low-precision fraction —
    the paper's Fig. 10 mechanism."""
    cfg = core.PrecisionConfig(levels=("f16",) * 5 + ("f32",), leaf=256)
    fracs = [core.census_potrf(n, cfg).lowp_fraction()
             for n in (512, 2048, 8192, 32768)]
    assert all(a < b for a, b in zip(fracs, fracs[1:])), fracs
