"""Per-architecture smoke tests: reduced config, forward + one train step
on CPU, output shapes + no NaNs. Full configs are only shape-checked via
jax.eval_shape (no allocation)."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import transformer as T


def _batch(cfg, rng, B=2, S=32):
    if cfg.family == "audio":
        toks = jax.random.randint(rng, (B, S, cfg.n_codebooks), 0, cfg.vocab)
    else:
        toks = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            rng, (B, cfg.n_img_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = configs.get_config(arch, smoke=True)
    rng = jax.random.PRNGKey(0)
    params = T.init_params(rng, cfg)
    batch = _batch(cfg, rng)

    logits, aux, _ = T.forward(params, batch, cfg, mode="train")
    B, S = batch["tokens"].shape[:2]
    if cfg.family == "audio":
        assert logits.shape == (B, S, cfg.n_codebooks, cfg.vocab)
    else:
        assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.isnan(logits).any()), "NaN logits"

    # one SGD train step reduces nothing fancy — just must be finite
    def loss(p):
        return T.loss_fn(p, batch, cfg)[0]

    l0, grads = jax.value_and_grad(loss)(params)
    assert jnp.isfinite(l0)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gnorm) and gnorm > 0
    params2 = jax.tree.map(lambda p, g: p - 0.01 * g.astype(p.dtype),
                           params, grads)
    l1 = loss(params2)
    assert jnp.isfinite(l1)


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_prefill_decode(arch):
    cfg = configs.get_config(arch, smoke=True)
    rng = jax.random.PRNGKey(1)
    params = T.init_params(rng, cfg)
    B, S = 2, 32
    batch = _batch(cfg, rng, B, S)
    full_logits, _, _ = T.forward(params, batch, cfg, mode="prefill")

    # prefill S-1, decode the last token, compare with the full pass
    pre = {k: (v[:, :S - 1] if v.shape[1:2] == (S,) else v)
           for k, v in batch.items()}
    _, _, caches = T.forward(params, pre, cfg, mode="prefill")
    caches = T.pad_caches(caches, S)
    tok = batch["tokens"][:, S - 1:S]
    dec_batch = {"tokens": tok}
    logits_d, _, new_caches = T.forward(params, dec_batch, cfg,
                                        mode="decode", caches=caches,
                                        pos=jnp.int32(S - 1))
    err = float(jnp.max(jnp.abs(logits_d[:, 0] - full_logits[:, -1])))
    assert err < 5e-4, f"{arch}: decode/full mismatch {err}"


# nominal parameter counts (billions) from the public configs
_EXPECTED_B = {
    "pixtral-12b": (11.0, 14.0),
    "nemotron-4-15b": (14.0, 17.0),
    "gemma-2b": (2.0, 3.2),
    "nemotron-4-340b": (320.0, 360.0),
    "granite-34b": (30.0, 38.0),
    "rwkv6-3b": (2.6, 3.6),
    "musicgen-large": (2.0, 3.4),
    "zamba2-2.7b": (2.2, 3.2),
    "deepseek-v2-lite-16b": (14.0, 18.0),
    "deepseek-v3-671b": (630.0, 700.0),
}


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_full_config_param_count(arch):
    """eval_shape the FULL config init — no memory allocated — and check
    the parameter count lands in the published ballpark."""
    cfg = configs.get_config(arch)
    shapes = jax.eval_shape(
        lambda k: T.init_params(k, cfg), jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(shapes)) / 1e9
    lo, hi = _EXPECTED_B[arch]
    assert lo <= n <= hi, f"{arch}: {n:.2f}B params outside [{lo},{hi}]B"
