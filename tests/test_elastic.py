"""Elastic fault-tolerance: a checkpoint written under one mesh resumes
bit-exactly under a different device count (8 -> 4 -> 1) — the node-
failure / rescale story (docs/ARCHITECTURE.md, "Model and training
integrations"). Needs 8 host devices (run
via tests/test_multidevice.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.launch.mesh import make_mesh
from repro.data import SyntheticLM
from repro.launch import sharding as SH
from repro.models.common import ModelConfig
from repro.optim import AdamWConfig
from repro.train import TrainConfig, init_state, make_train_step

needs8 = pytest.mark.skipif(jax.device_count() < 8,
                            reason="needs 8 host devices")

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  d_ff=128, vocab=128, n_heads=4, n_kv=2, mlp="swiglu",
                  max_seq=32, remat=False)
TCFG = TrainConfig(adam=AdamWConfig(lr=1e-2, warmup=0, total_steps=50))


def _mesh(data, model):
    return make_mesh((data, model), ("data", "model"))


def _state_shardings(state, mesh):
    p_shapes = jax.eval_shape(lambda s: s, state)["params"]
    p_sh = SH.param_shardings(p_shapes, CFG, mesh)
    o_sh = SH.opt_state_shardings(
        jax.eval_shape(lambda s: s, state)["opt"], p_sh, mesh)
    from jax.sharding import NamedSharding, PartitionSpec as P
    return {"params": p_sh, "opt": o_sh,
            "step": NamedSharding(mesh, P())}


@needs8
def test_elastic_resume_across_meshes(tmp_path):
    data = SyntheticLM(CFG.vocab, batch=8, seq=16, seed=0)

    # train 3 steps on an 8-device (4,2) mesh, checkpoint
    mesh8 = _mesh(4, 2)
    sharder8 = SH.make_sharder(mesh8, multi_pod=False, batch=8)
    state = init_state(jax.random.PRNGKey(0), CFG, TCFG)
    with mesh8:
        step8 = jax.jit(make_train_step(CFG, TCFG, sharder8))
        for i in range(3):
            state, _ = step8(state, jax.tree.map(jnp.asarray, data.get(i)))
    ckpt.save(str(tmp_path), 3, state, blocking=True)

    # continue 2 steps on 8 devices (reference trajectory)
    ref = state
    with mesh8:
        for i in range(3, 5):
            ref, mref = step8(ref, jax.tree.map(jnp.asarray, data.get(i)))

    # resume on a 4-device (2,2) mesh and on a single device
    for dm in [(2, 2), (1, 1)]:
        mesh = _mesh(*dm)
        sharder = SH.make_sharder(mesh, multi_pod=False, batch=8)
        template = init_state(jax.random.PRNGKey(0), CFG, TCFG)
        shardings = _state_shardings(template, mesh)
        with mesh:
            restored, s0 = ckpt.restore(str(tmp_path), template,
                                        shardings=shardings)
            assert s0 == 3
            step = jax.jit(make_train_step(CFG, TCFG, sharder))
            for i in range(3, 5):
                restored, m = step(restored,
                                   jax.tree.map(jnp.asarray, data.get(i)))
        assert abs(float(m["loss"]) - float(mref["loss"])) < 1e-4, dm
        # ref/restored live on different meshes: compare on host
        d = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
                for a, b in zip(jax.tree.leaves(ref["params"]),
                                jax.tree.leaves(restored["params"])))
        assert d < 1e-4, (dm, d)
