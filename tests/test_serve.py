"""Serving engine tests: greedy generate matches teacher-forced argmax,
cache padding, batched audio generation; SolverEngine factor-cache
correctness (fingerprint, LRU) and BatchScheduler batching/ordering."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as T
from repro.serve import BatchScheduler, SolverEngine, engine


def _greedy_reference(params, cfg, prompt, n_tokens, extra=None):
    """Re-run the full forward for every generated token (O(n^2) but
    trivially correct)."""
    toks = prompt
    for _ in range(n_tokens):
        batch = {"tokens": toks}
        if extra:
            batch.update(extra)
        logits, _, _ = T.forward(params, batch, cfg, mode="prefill",
                                 last_only=True)
        nxt = jnp.argmax(logits[:, -1], axis=-1)
        nxt = nxt[:, None, :] if cfg.family == "audio" else nxt[:, None]
        toks = jnp.concatenate([toks, nxt], axis=1)
    return toks[:, prompt.shape[1]:]


@pytest.mark.parametrize("arch", ["gemma-2b", "rwkv6-3b", "zamba2-2.7b",
                                  "deepseek-v2-lite-16b"])
def test_generate_matches_teacher_forcing(arch):
    cfg = configs.get_config(arch, smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    B, S, n_new = 2, 12, 6
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab)
    got = engine.generate(params, {"tokens": prompt}, cfg,
                          n_tokens=n_new, max_len=S + n_new)
    want = _greedy_reference(params, cfg, prompt, n_new)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_generate_audio_shapes():
    cfg = configs.get_config("musicgen-large", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8, 4), 0,
                                cfg.vocab)
    out = engine.generate(params, {"tokens": prompt}, cfg, n_tokens=5,
                          max_len=16)
    assert out.shape == (2, 5, 4)
    assert (np.asarray(out) < cfg.vocab).all()


# ---------------------------------------------------------------------------
# SolverEngine factor cache + BatchScheduler
# ---------------------------------------------------------------------------
def _spd(n, seed=0):
    rng = np.random.default_rng(seed)
    m = rng.uniform(-1, 1, (n, n))
    return (m @ m.T + n * np.eye(n)).astype(np.float32)


def _rhs(a, seed):
    n = a.shape[0]
    return (a @ np.random.default_rng(seed).standard_normal(n)).astype(
        np.float32)


def test_factor_cache_detects_stale_key():
    """Regression: a reused cache_key with DIFFERENT matrix data used to
    silently solve against the stale factor. The fingerprint must force
    refactorization (and the result must be accurate for the new A)."""
    n = 256
    a1, a2 = _spd(n, seed=1), _spd(n, seed=2)
    b2 = _rhs(a2, seed=3)
    eng = SolverEngine("f16_f32", max_sweeps=8)
    eng.solve(a1, _rhs(a1, seed=4), cache_key="shared")
    x, info = eng.solve(a2, b2, target_digits=6.0, cache_key="shared")
    assert not info.factor_cached          # stale entry was NOT reused
    rr = np.linalg.norm(a2 @ np.asarray(x) - b2) / np.linalg.norm(b2)
    assert rr <= 1e-6, rr
    # and the replaced entry now serves a2
    _, info2 = eng.solve(a2, b2, cache_key="shared")
    assert info2.factor_cached


def test_factor_cache_lru_bound():
    n = 192
    mats = [_spd(n, seed=s) for s in range(4)]
    eng = SolverEngine("f16_f32", max_sweeps=6, max_cached_factors=2)
    for i, a in enumerate(mats[:3]):
        eng.solve(a, _rhs(a, seed=i), cache_key=f"k{i}")
    assert eng.cached_keys() == ["k1", "k2"]   # k0 evicted, LRU first
    _, info = eng.solve(mats[0], _rhs(mats[0], seed=9), cache_key="k0")
    assert not info.factor_cached              # k0 had to refactorize
    # a hit refreshes recency: touch k2, then insert k3 -> k0 evicted
    eng.solve(mats[2], _rhs(mats[2], seed=10), cache_key="k2")
    eng.solve(mats[3], _rhs(mats[3], seed=11), cache_key="k3")
    assert eng.cached_keys() == ["k2", "k3"]


def test_scheduler_batches_requests_sharing_a_factor():
    n = 256
    a, a_other = _spd(n, seed=5), _spd(n, seed=6)
    eng = SolverEngine("f16_f32", max_sweeps=8)
    sch = BatchScheduler(eng, max_batch=8)
    bs = [_rhs(a, seed=10 + i) for i in range(4)]
    ids = [sch.submit(a, b, target_digits=6.0, cache_key="k")
           for b in bs]
    b_other = _rhs(a_other, seed=20)
    id_other = sch.submit(a_other, b_other, cache_key="other")
    assert len(sch) == 5
    out = sch.drain()
    assert len(sch) == 0 and set(out) == {*ids, id_other}
    for i, (rid, b) in enumerate(zip(ids, bs)):
        x, info = out[rid]
        rr = np.linalg.norm(a @ np.asarray(x) - b) / np.linalg.norm(b)
        assert rr <= 1e-6, rr                   # each request got ITS x
        assert info.batch_size == 4             # all four rode one call
        assert info.batch_index == i            # in submission order
        assert info.converged
    x, info = out[id_other]
    assert info.batch_size == 1
    rr = (np.linalg.norm(a_other @ np.asarray(x) - b_other)
          / np.linalg.norm(b_other))
    assert rr <= 1e-6, rr
    # a second drain against the same key reuses the cached factor
    rid2 = sch.submit(a, bs[0], cache_key="k")
    assert out[ids[0]][1].factor_cached is False
    assert sch.drain()[rid2][1].factor_cached is True


def test_scheduler_never_batches_mismatched_matrices():
    """Two different matrices submitted under the SAME cache_key in one
    drain must land in different batches (fingerprint grouping), and
    both must come back accurate."""
    n = 192
    a1, a2 = _spd(n, seed=7), _spd(n, seed=8)
    b1, b2 = _rhs(a1, seed=1), _rhs(a2, seed=2)
    sch = BatchScheduler(SolverEngine("f16_f32", max_sweeps=8))
    i1 = sch.submit(a1, b1, cache_key="k")
    i2 = sch.submit(a2, b2, cache_key="k")
    out = sch.drain()
    assert out[i1][1].batch_size == 1 and out[i2][1].batch_size == 1
    for a, b, rid in [(a1, b1, i1), (a2, b2, i2)]:
        x = np.asarray(out[rid][0])
        assert np.linalg.norm(a @ x - b) / np.linalg.norm(b) <= 1e-6


def test_scheduler_respects_max_batch_and_mixed_targets():
    n = 256
    a = _spd(n, seed=11)
    eng = SolverEngine("f16_f32", max_sweeps=8)
    sch = BatchScheduler(eng, max_batch=3)
    targets = [2.0, 6.0, 2.0, 6.0, 2.0]
    ids = [sch.submit(a, _rhs(a, seed=30 + i), target_digits=t,
                      cache_key="k")
           for i, t in enumerate(targets)]
    out = sch.drain()
    sizes = [out[r][1].batch_size for r in ids]
    assert sizes == [3, 3, 3, 2, 2]            # chunked at max_batch
    for rid, t in zip(ids, targets):
        info = out[rid][1]
        assert info.converged and info.residual <= 10.0 ** -t
        assert info.target_digits == t         # per-request target kept


def test_scheduler_drain_failure_preserves_other_requests():
    """A failing batch (non-SPD matrix) must not lose other work: solved
    results come back from the next drain, unattempted requests stay
    queued, and the failing batch lands in scheduler.failed."""
    n = 128
    a = _spd(n, seed=17)
    bad = -np.eye(n, dtype=np.float32)          # not SPD: cholesky -> nan
    sch = BatchScheduler(SolverEngine("f16_f32", max_sweeps=6))
    ok_id = sch.submit(a, _rhs(a, seed=1), cache_key="good")
    bad_id = sch.submit(bad, np.ones(n, np.float32), cache_key="bad")
    later_id = sch.submit(a, _rhs(a, seed=2), cache_key="good2")

    class Boom(RuntimeError):
        pass

    orig = sch.engine.solve_batched

    def exploding(a_, bs, **kw):                # deterministic failure
        if kw.get("cache_key") == "bad":
            raise Boom("not SPD")
        return orig(a_, bs, **kw)

    sch.engine.solve_batched = exploding
    with pytest.raises(Boom):
        sch.drain()
    assert [r.request_id for r in sch.failed] == [bad_id]
    assert [r.request_id for r in sch._queue] == [later_id]
    out = sch.drain()                           # stashed + re-queued work
    assert set(out) == {ok_id, later_id}
    for rid, seed, mat in [(ok_id, 1, a), (later_id, 2, a)]:
        x, info = out[rid]
        b = _rhs(mat, seed=seed)
        rr = np.linalg.norm(mat @ np.asarray(x) - b) / np.linalg.norm(b)
        assert rr <= 1e-6 and info.converged


def test_scheduler_multi_column_request():
    """(n, k) block requests batch next to vector requests and come back
    with their input arity."""
    n = 192
    a = _spd(n, seed=13)
    blk = np.stack([_rhs(a, seed=40), _rhs(a, seed=41)], axis=1)
    vec = _rhs(a, seed=42)
    sch = BatchScheduler(SolverEngine("f16_f32", max_sweeps=8))
    i_blk = sch.submit(a, blk, cache_key="k")
    i_vec = sch.submit(a, vec, cache_key="k")
    out = sch.drain()
    x_blk, info_blk = out[i_blk]
    x_vec, info_vec = out[i_vec]
    assert x_blk.shape == (n, 2) and x_vec.shape == (n,)
    assert info_blk.batch_size == info_vec.batch_size == 2
    rr = np.linalg.norm(a @ np.asarray(x_blk) - blk) / np.linalg.norm(blk)
    assert rr <= 1e-5 and info_blk.converged and info_vec.converged


def test_generate_sampling_reproducible():
    cfg = configs.get_config("gemma-2b", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                cfg.vocab)
    a = engine.generate(params, {"tokens": prompt}, cfg, n_tokens=4,
                        temperature=1.0, rng=jax.random.PRNGKey(7),
                        max_len=16)
    b = engine.generate(params, {"tokens": prompt}, cfg, n_tokens=4,
                        temperature=1.0, rng=jax.random.PRNGKey(7),
                        max_len=16)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
