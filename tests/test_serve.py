"""Serving engine tests: greedy generate matches teacher-forced argmax,
cache padding, batched audio generation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as T
from repro.serve import engine


def _greedy_reference(params, cfg, prompt, n_tokens, extra=None):
    """Re-run the full forward for every generated token (O(n^2) but
    trivially correct)."""
    toks = prompt
    for _ in range(n_tokens):
        batch = {"tokens": toks}
        if extra:
            batch.update(extra)
        logits, _, _ = T.forward(params, batch, cfg, mode="prefill",
                                 last_only=True)
        nxt = jnp.argmax(logits[:, -1], axis=-1)
        nxt = nxt[:, None, :] if cfg.family == "audio" else nxt[:, None]
        toks = jnp.concatenate([toks, nxt], axis=1)
    return toks[:, prompt.shape[1]:]


@pytest.mark.parametrize("arch", ["gemma-2b", "rwkv6-3b", "zamba2-2.7b",
                                  "deepseek-v2-lite-16b"])
def test_generate_matches_teacher_forcing(arch):
    cfg = configs.get_config(arch, smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    B, S, n_new = 2, 12, 6
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab)
    got = engine.generate(params, {"tokens": prompt}, cfg,
                          n_tokens=n_new, max_len=S + n_new)
    want = _greedy_reference(params, cfg, prompt, n_new)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_generate_audio_shapes():
    cfg = configs.get_config("musicgen-large", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8, 4), 0,
                                cfg.vocab)
    out = engine.generate(params, {"tokens": prompt}, cfg, n_tokens=5,
                          max_len=16)
    assert out.shape == (2, 5, 4)
    assert (np.asarray(out) < cfg.vocab).all()


def test_generate_sampling_reproducible():
    cfg = configs.get_config("gemma-2b", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                cfg.vocab)
    a = engine.generate(params, {"tokens": prompt}, cfg, n_tokens=4,
                        temperature=1.0, rng=jax.random.PRNGKey(7),
                        max_len=16)
    b = engine.generate(params, {"tokens": prompt}, cfg, n_tokens=4,
                        temperature=1.0, rng=jax.random.PRNGKey(7),
                        max_len=16)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
