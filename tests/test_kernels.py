"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
sweeping shapes and dtypes (assignment deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _rand(shape, dtype=np.float32):
    return RNG.standard_normal(shape).astype(dtype)


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 512, 128),
                                   (300, 200, 180), (64, 1000, 72)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_qgemm_shapes_dtypes(m, k, n, dtype):
    a = jnp.asarray(_rand((m, k)), dtype)
    b = jnp.asarray(_rand((k, n)), dtype)
    got = ops.qgemm(a, b, 1.7, impl="interpret")
    want = ref.qgemm_ref(a, b, scale=1.7)
    # k-chunked accumulation order differs from the single-dot oracle
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=1e-4)


@pytest.mark.parametrize("trans_b", [False, True])
@pytest.mark.parametrize("beta", [0.0, 1.0, -0.5])
def test_qgemm_epilogue(trans_b, beta):
    a = jnp.asarray(_rand((192, 160)), jnp.bfloat16)
    b_shape = (96, 160) if trans_b else (160, 96)
    b = jnp.asarray(_rand(b_shape), jnp.bfloat16)
    c = _rand((192, 96))
    got = ops.qgemm(a, b, 0.3, c=c, beta=beta, trans_b=trans_b,
                    impl="interpret")
    want = ref.qgemm_ref(a, b, trans_b=trans_b, scale=0.3, c=c, beta=beta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,k", [(128, 1), (256, 4), (300, 3), (129, 130),
                                 (512, 8)])
def test_residual_fused(n, k):
    a = _rand((n, n))
    x = _rand((n, k))
    b = _rand((n, k))
    got = ops.residual(a, x, b, impl="interpret")
    want = ref.residual_ref(a, x, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-3)


def test_residual_fused_vector():
    n = 200
    a = _rand((n, n))
    x = _rand((n,))
    b = _rand((n,))
    got = ops.residual(a, x, b, impl="interpret")
    assert got.shape == (n,)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.residual_ref(a, x, b)),
                               rtol=2e-4, atol=2e-3)


def _panel_meta(levels, nt, panel=0):
    from repro.core.plan import build_plan
    from repro.core.precision import PrecisionConfig
    cfg = PrecisionConfig(levels=levels, leaf=128)
    return build_plan((nt + 1 + panel) * 128, cfg).panel_meta(panel)


def _panel_operands(nt, b=128, seed=3):
    rng = np.random.default_rng(seed)
    linv = np.tril(rng.standard_normal((b, b)).astype(np.float32))
    linv[np.diag_indices(b)] += 3.0
    a21 = rng.standard_normal((nt * b, b)).astype(np.float32)
    c = rng.standard_normal((nt * b, nt * b)).astype(np.float32)
    return jnp.asarray(linv), jnp.asarray(a21), jnp.asarray(c)


@pytest.mark.parametrize("levels,nt", [
    (("f32",), 2), (("f16", "f32"), 3), (("f16", "f16", "f32"), 4),
    (("bf16", "f32"), 2), (("int8", "f32"), 3)])
def test_panel_update_fused(levels, nt):
    """Fused panel kernel (interpret) is bit-identical to the oracle
    across ladder mixes: same per-tile rounding, same update tiling."""
    meta = _panel_meta(levels, nt)
    linv, a21, c = _panel_operands(nt)
    kw = dict(store_names=meta.store_names, store_quants=meta.store_quants,
              pair_names=meta.pair_names, pair_quants=meta.pair_quants)
    l21r, cr = ref.panel_update_ref(linv, a21, c, **kw)
    l21k, ck = ops.panel_update(linv, a21, c, impl="interpret", **kw)
    np.testing.assert_array_equal(np.asarray(l21r), np.asarray(l21k))
    np.testing.assert_array_equal(np.asarray(cr), np.asarray(ck))


def test_panel_update_no_rounding():
    """storage_rounding=False: raw f32 trsm + syrk, upper c preserved."""
    meta = _panel_meta(("f16", "f32"), 3)
    linv, a21, c = _panel_operands(3)
    kw = dict(store_names=meta.store_names, store_quants=meta.store_quants,
              pair_names=meta.pair_names, pair_quants=meta.pair_quants,
              rounding=False)
    l21, cu = ops.panel_update(linv, a21, c, impl="interpret", **kw)
    l21r, cur = ref.panel_update_ref(linv, a21, c, **kw)
    np.testing.assert_array_equal(np.asarray(l21), np.asarray(l21r))
    np.testing.assert_array_equal(np.asarray(cu), np.asarray(cur))
    # strictly-upper tiles of c pass through untouched
    got = np.asarray(cu)
    want = np.asarray(c)
    b = 128
    for i in range(3):
        for j in range(i + 1, 3):
            np.testing.assert_array_equal(
                got[i * b:(i + 1) * b, j * b:(j + 1) * b],
                want[i * b:(i + 1) * b, j * b:(j + 1) * b])


def test_trsm_leaf_accepts_precomputed_linv():
    """Satellite: trsm_leaf(linv=) skips the leaf inversion and matches
    the from-scratch call bitwise (jnp dispatch path included)."""
    from repro.kernels import potrf as _potrf
    from repro.kernels import trsm as _trsm
    rng = np.random.default_rng(9)
    l = np.tril(rng.standard_normal((128, 128)).astype(np.float32))
    l[np.diag_indices(128)] += 8.0
    b = rng.standard_normal((300, 128)).astype(np.float32)
    x1 = _trsm.trsm_leaf(jnp.asarray(b), jnp.asarray(l), interpret=True)
    linv = _potrf.tri_inv_leaf(jnp.asarray(l), interpret=True)
    x2 = _trsm.trsm_leaf(jnp.asarray(b), linv=linv, interpret=True)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    # ops-level jnp dispatch with a provided linv turns into one GEMM
    x3 = ops.trsm(jnp.asarray(b), jnp.asarray(l), linv=linv, impl="jnp")
    np.testing.assert_allclose(np.asarray(x3), np.asarray(x1),
                               rtol=1e-4, atol=1e-4)


def test_residual_f64_routes_to_oracle():
    """f64 residuals (the x64 accuracy path) must bypass the fused
    kernel's f32 accumulator bit-for-bit."""
    from jax.experimental import enable_x64
    with enable_x64():
        n = 96
        a = jnp.asarray(RNG.standard_normal((n, n)))
        x = jnp.asarray(RNG.standard_normal(n))
        b = jnp.asarray(RNG.standard_normal(n))
        got = ops.residual(a, x, b, impl="interpret")
        assert got.dtype == jnp.float64
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(ref.residual_ref(a, x, b)))


@pytest.mark.parametrize("n", [128, 256, 384, 512])
def test_potrf_leaf(n):
    m = _rand((n, n))
    a = m @ m.T + n * np.eye(n, dtype=np.float32)
    got = ops.potrf(a, impl="interpret")
    want = ref.potrf_ref(a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4 * n)


@pytest.mark.parametrize("n", [128, 256, 512])
def test_tri_inv_leaf(n):
    l = np.tril(_rand((n, n))) + np.sqrt(n) * 4 * np.eye(n,
                                                         dtype=np.float32)
    got = ops.tri_inv(l, impl="interpret")
    want = ref.tri_inv_ref(l)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("m,n", [(128, 128), (700, 256), (1024, 128),
                                 (65, 384)])
def test_trsm_leaf(m, n):
    l = np.tril(_rand((n, n))) + 4 * np.sqrt(n) * np.eye(n,
                                                         dtype=np.float32)
    b = _rand((m, n))
    got = ops.trsm(b, l, impl="interpret")
    want = ref.trsm_ref(b, l)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("side,trans", [("left", False), ("left", True)])
def test_trsm_left_forms(side, trans):
    n, m = 256, 192
    l = np.tril(_rand((n, n))) + 4 * np.sqrt(n) * np.eye(n,
                                                         dtype=np.float32)
    b = _rand((n, m))
    got = ops.trsm(b, l, side=side, trans=trans, impl="interpret")
    want = ref.trsm_ref(b, l, side=side, trans=trans)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("n,k", [(128, 128), (256, 1000), (256, 64)])
@pytest.mark.parametrize("scale,beta", [(1.0, 1.0), (0.5, -0.25)])
def test_syrk_leaf(n, k, scale, beta):
    c = _rand((n, n))
    a = _rand((n, k))
    got = ops.syrk(c, a, scale, beta, impl="interpret")
    want = ref.syrk_ref(c, a, scale=scale, beta=beta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("n,k", [(512, 256), (640, 300), (500, 513)])
def test_syrk_packed(n, k):
    c = _rand((n, n))
    a = _rand((n, k))
    got = ops.syrk(c, a, 0.7, 0.9, packed=True, impl="interpret")
    want = ref.syrk_ref(c, a, scale=0.7, beta=0.9)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_syrk_packed_preserves_upper():
    n, k = 256, 128
    c = _rand((n, n))
    a = _rand((n, k))
    got = np.asarray(ops.syrk(c, a, 1.0, 1.0, packed=True,
                              impl="interpret"))
    iu = np.triu_indices(n, 1)
    np.testing.assert_allclose(got[iu], c[iu], rtol=1e-6)


def test_tri_decode_exact():
    """Triangular index decode must be exact over a large range."""
    from repro.kernels.syrk import _tri_decode
    t = jnp.arange(0, 200000, dtype=jnp.int32)
    i, j = jax.jit(_tri_decode)(t)
    i, j = np.asarray(i), np.asarray(j)
    # reconstruct and compare
    np.testing.assert_array_equal(i * (i + 1) // 2 + j, np.arange(200000))
    assert (j <= i).all()
