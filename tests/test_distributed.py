"""Distributed precision-planned Cholesky on a forced 4-host-device CPU
mesh: ``dist_cholesky == blocked_potrf`` per PAPER_CONFIGS entry, both
collective schedules, plan-driven compressed collectives, the
distributed solve, the serve engine's mesh mode, and the scheduler's
async drain. The shard-plan and async-drain tests are host-side and run
in the main 1-device session too; the mesh tests are driven via
tests/test_multidevice.py, or directly:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m pytest tests/test_distributed.py -q
"""
import dataclasses
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.core as core
from repro.core import distributed as dist
from repro.core.plan import build_plan, shard
from repro.launch.mesh import make_mesh

needs4 = pytest.mark.skipif(jax.device_count() < 4,
                            reason="needs 4 host devices")

#: ladder-roundoff equivalence tolerance per coarsest level, as in
#: tests/test_blocked.py
_TOL = {"f16": 5e-3, "bf16": 4e-2, "int8": 4e-2, "f32": 5e-6, "f64": 1e-12}

CONFIGS = [k for k in core.PAPER_CONFIGS if "f64" not in k]
CONFIGS_F64 = [k for k in core.PAPER_CONFIGS if "f64" in k]


def _spd64(n, seed=2):
    rng = np.random.default_rng(seed)
    m = rng.uniform(-1, 1, (n, n))
    return m @ m.T + n * np.eye(n)


def _shard_a(a64, mesh, dtype=jnp.float32):
    return jax.device_put(jnp.asarray(a64, dtype),
                          NamedSharding(mesh, P("model", None)))


def _rel(l, ref):
    l = np.asarray(l, np.float64)
    ref = np.asarray(ref, np.float64)
    return np.abs(l - ref).max() / np.abs(ref).max()


# ---------------------------------------------------------------------------
# dist_cholesky == blocked_potrf (the single-device planned engine)
# ---------------------------------------------------------------------------
@needs4
@pytest.mark.parametrize("name", CONFIGS)
def test_dist_matches_blocked(name):
    """Default schedule (diag broadcast + plan-compressed collectives)
    matches the single-device blocked engine to ladder roundoff."""
    cfg = core.PAPER_CONFIGS[name]
    n = 1024
    mesh = make_mesh((4,), ("model",))
    a64 = _spd64(n)
    ref = core.blocked_potrf(jnp.asarray(a64, jnp.float32), cfg)
    l = dist.dist_cholesky(_shard_a(a64, mesh), mesh, cfg)
    rel = _rel(l, ref)
    assert rel < _TOL[cfg.levels[0]], (name, rel)
    assert np.abs(np.triu(np.asarray(l), 1)).max() == 0.0


@needs4
@pytest.mark.parametrize("name", CONFIGS_F64)
def test_dist_matches_blocked_f64(name):
    """f64-ladder entries (need x64; run by tests/test_multidevice.py
    in a JAX_ENABLE_X64 subprocess)."""
    if not jax.config.jax_enable_x64:
        pytest.skip("f64 ladders need JAX_ENABLE_X64=1")
    cfg = core.PAPER_CONFIGS[name]
    n = 1024
    mesh = make_mesh((4,), ("model",))
    a64 = _spd64(n)
    ref = core.blocked_potrf(jnp.asarray(a64, jnp.float64), cfg)
    l = dist.dist_cholesky(_shard_a(a64, mesh, jnp.float64), mesh, cfg)
    assert _rel(l, ref) < _TOL[cfg.levels[0]], name


@needs4
@pytest.mark.parametrize("bd", [True, False])
@pytest.mark.parametrize("cc", [True, False])
def test_dist_schedules_multitile(bd, cc):
    """Both collective schedules x compressed/full gathers on a w > leaf
    layout (leaf=128 -> 2 tile rows per shard: the local diagonal
    factorization dispatches the fused panel kernel and each shard
    storage-rounds its block-row slice of the solved panel)."""
    cfg = dataclasses.replace(core.PAPER_CONFIGS["f16_f32"], leaf=128)
    n = 1024
    mesh = make_mesh((4,), ("model",))
    a64 = _spd64(n)
    ref = core.blocked_potrf(jnp.asarray(a64, jnp.float32), cfg)
    l = dist.dist_cholesky(_shard_a(a64, mesh), mesh, cfg,
                           broadcast_diag_only=bd, compress_comm=cc)
    rel = _rel(l, ref)
    assert rel < _TOL["f16"], (bd, cc, rel)
    # and against the true factor (sanity beyond engine equivalence)
    want = np.linalg.cholesky(a64)
    assert _rel(l, want) < 5e-3, (bd, cc)


@needs4
def test_dist_solve():
    n = 1024
    mesh = make_mesh((4,), ("model",))
    cfg = core.PrecisionConfig(levels=("f32",), leaf=128)
    a64 = _spd64(n)
    rng = np.random.default_rng(0)
    xt = rng.standard_normal((n, 3))
    b = jax.device_put(jnp.asarray(a64 @ xt, jnp.float32),
                       NamedSharding(mesh, P("model", None)))
    x = dist.dist_cholesky_solve(_shard_a(a64, mesh), b, mesh, cfg)
    rel = np.abs(np.asarray(x, np.float64) - xt).max() / np.abs(xt).max()
    assert rel < 1e-4, rel


# ---------------------------------------------------------------------------
# sharded plan (host-side: runs without devices)
# ---------------------------------------------------------------------------
def test_sharded_plan_comm_schedule():
    """Collective precision follows the plan: early panels move at the
    ladder's coarse level, panels whose every trailing consumer computes
    fine are gathered losslessly."""
    cfg = dataclasses.replace(core.PAPER_CONFIGS["bf16x3_f32"], leaf=128)
    sp = core.shard(build_plan(1024, cfg), 4)
    names = [sp.comm_name(j) for j in range(4)]
    assert names[0] == "bf16" and names[-1] == "f32", names
    # pure ladders compress every panel; f32 ladders none
    sp16 = shard(build_plan(1024, dataclasses.replace(
        core.PAPER_CONFIGS["pure_f16"], leaf=128)), 4)
    assert all(sp16.comm_name(j) == "f16" for j in range(4))
    sp32 = shard(build_plan(1024, core.PAPER_CONFIGS["pure_f32"]), 4)
    assert all(sp32.comm_name(j) == "f32" for j in range(4))
    assert "panel 0: comm=bf16" in sp.describe()


def test_sharded_plan_views_match_parent():
    """diag_plan / store_codes are views of the global tables, not a
    fresh local recursion."""
    cfg = dataclasses.replace(core.PAPER_CONFIGS["f16x3_f32"], leaf=128)
    plan = build_plan(2048, cfg)
    sp = shard(plan, 4)
    assert sp.tps == 4 and sp.panel_width == 512
    for j in (0, 3):
        dp = sp.diag_plan(j)
        assert dp.ntiles == 4
        for r in range(4):
            for c in range(r + 1):
                gi, gj = j * 4 + r, j * 4 + c
                assert dp.level(r, c) == plan.level(gi, gj)
                assert dp.name(r, c) == plan.name(gi, gj)
        codes = sp.store_codes(j)
        assert codes.shape == (16, 4)
        for i in range(16):
            for c in range(4):
                assert sp.names[codes[i, c]] == plan.store_name(i, j * 4 + c)
    # the deepest diagonal sub-block is NOT what a fresh size-512 plan
    # would assign (global levels are deeper): spot-check the far corner
    fresh = build_plan(512, cfg)
    glob = sp.diag_plan(3)
    assert glob.level(3, 0) >= fresh.level(3, 0)


# ---------------------------------------------------------------------------
# serve engine mesh mode
# ---------------------------------------------------------------------------
@needs4
def test_engine_mesh_mode_routes_and_caches():
    from repro.serve import SolverEngine
    mesh = make_mesh((4,), ("model",))
    eng = SolverEngine("bf16_f32", max_sweeps=8, mesh=mesh,
                       dist_threshold=512)
    n = 1024
    a = np.asarray(_spd64(n, seed=7), np.float32)
    rng = np.random.default_rng(1)
    b = rng.standard_normal(n).astype(np.float32)
    x, info = eng.solve(a, b, target_digits=6, cache_key="big")
    assert info.distributed and info.converged and not info.factor_cached
    rel = np.abs(a.astype(np.float64) @ np.asarray(x, np.float64)
                 - b).max() / np.abs(b).max()
    assert rel < 1e-6, rel
    # second request reuses the SHARDED factor per fingerprint
    x2, info2 = eng.solve(a, 2.0 * b, target_digits=6, cache_key="big")
    assert info2.distributed and info2.factor_cached and info2.converged
    # below-threshold (and non-divisible) sizes stay on the local path
    asmall = np.asarray(_spd64(192, seed=9), np.float32)
    bs = rng.standard_normal(192).astype(np.float32)
    x3, info3 = eng.solve(asmall, bs, target_digits=6)
    assert not info3.distributed and info3.converged


# ---------------------------------------------------------------------------
# async drain (host-side: runs without devices)
# ---------------------------------------------------------------------------
def _spd32(n, seed):
    return np.asarray(_spd64(n, seed), np.float32)


def test_async_drain_batches_and_orders():
    """Futures resolve with each request's own solution; requests that
    land in one batching window share one refine call, in submission
    order."""
    from repro.serve import BatchScheduler, SolverEngine
    a = _spd32(64, 1)
    rng = np.random.default_rng(0)
    bs = [rng.standard_normal(64).astype(np.float32) for _ in range(4)]
    sch = BatchScheduler(SolverEngine("bf16_f32", max_sweeps=8),
                         max_wait_ms=300)
    sch.start()
    try:
        futs = [sch.submit_async(a, b, target_digits=6, cache_key="k")
                for b in bs]
        outs = [f.result(timeout=600) for f in futs]
    finally:
        sch.stop()
    assert len(sch) == 0
    for i, ((x, info), b) in enumerate(zip(outs, bs)):
        rel = np.abs(a @ np.asarray(x, np.float32) - b).max() / \
            np.abs(b).max()
        assert rel < 1e-5, (i, rel)
        assert info.batch_size == 4 and info.batch_index == i, info


def test_async_deadline_drains_lone_request():
    """A lone request is served once its max_wait_ms deadline passes —
    no follow-up submission or manual drain needed."""
    from repro.serve import BatchScheduler, SolverEngine
    a = _spd32(64, 2)
    b = np.random.default_rng(1).standard_normal(64).astype(np.float32)
    sch = BatchScheduler(SolverEngine("bf16_f32", max_sweeps=8),
                         max_wait_ms=50)
    sch.start()
    try:
        t0 = time.monotonic()
        x, info = sch.submit_async(a, b, target_digits=5).result(timeout=600)
        waited = time.monotonic() - t0
    finally:
        sch.stop()
    assert info.converged and info.batch_size == 1
    assert waited >= 0.05 * 0.5    # the window was actually observed


def test_async_admission_control():
    """A submission that would put more distinct factors in flight than
    the cache holds is rejected, not queued."""
    from repro.serve import BatchScheduler, SchedulerOverload, SolverEngine
    sch = BatchScheduler(SolverEngine("bf16_f32"), max_wait_ms=5000,
                         max_pending_factors=2)
    sch.start()
    b = np.random.default_rng(2).standard_normal(64).astype(np.float32)
    try:
        f1 = sch.submit_async(_spd32(64, 3), b, cache_key="k1")
        f2 = sch.submit_async(_spd32(64, 4), b, cache_key="k2")
        # same matrix again: not a NEW factor, admitted
        f3 = sch.submit_async(_spd32(64, 3), b, cache_key="k1")
        with pytest.raises(SchedulerOverload):
            sch.submit_async(_spd32(64, 5), b, cache_key="k3")
    finally:
        sch.stop()               # drains the admitted requests
    for f in (f1, f2, f3):
        _, info = f.result(timeout=60)
        assert info.converged


def test_async_requires_started_worker():
    from repro.serve import BatchScheduler, SolverEngine
    sch = BatchScheduler(SolverEngine("bf16_f32"), max_wait_ms=10)
    with pytest.raises(AssertionError):
        sch.submit_async(_spd32(64, 6), np.ones(64, np.float32))
