"""Fixture-HLO suite for the census parser.

Hand-written HLO text pinning the parser behaviors the precision auditor
leans on: nested while-loop trip multipliers, tuple-typed carries,
typed-inline vs name-resolved dot operands, mixed-dtype operand
classification, and per-wire-dtype collective bytes staying
byte-compatible with the aggregate counters.
"""
import pytest

from repro.launch import hloparse

# -- fixtures -----------------------------------------------------------

# dot inside a while(3) whose body contains a while(4): multiplier 12
NESTED_WHILES = """\
HloModule nested

%inner_cond (arg.i: (f32[128,128], s32[])) -> pred[] {
  %arg.i = (f32[128,128], s32[]) parameter(0)
  %it.i = s32[] get-tuple-element((f32[128,128], s32[]) %arg.i), index=1
  %c4 = s32[] constant(4)
  ROOT %lt.i = pred[] compare(s32[] %it.i, s32[] %c4), direction=LT
}

%inner_body (arg.ib: (f32[128,128], s32[])) -> (f32[128,128], s32[]) {
  %arg.ib = (f32[128,128], s32[]) parameter(0)
  %x = f32[128,128] get-tuple-element((f32[128,128], s32[]) %arg.ib), index=0
  %dot.i = f32[128,128] dot(f32[128,128] %x, f32[128,128] %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %it.ib = s32[] get-tuple-element((f32[128,128], s32[]) %arg.ib), index=1
  %c1 = s32[] constant(1)
  %inc = s32[] add(s32[] %it.ib, s32[] %c1)
  ROOT %tup.ib = (f32[128,128], s32[]) tuple(f32[128,128] %dot.i, s32[] %inc)
}

%outer_cond (arg.o: (f32[128,128], s32[])) -> pred[] {
  %arg.o = (f32[128,128], s32[]) parameter(0)
  %it.o = s32[] get-tuple-element((f32[128,128], s32[]) %arg.o), index=1
  %c3 = s32[] constant(3)
  ROOT %lt.o = pred[] compare(s32[] %it.o, s32[] %c3), direction=LT
}

%outer_body (arg.ob: (f32[128,128], s32[])) -> (f32[128,128], s32[]) {
  %arg.ob = (f32[128,128], s32[]) parameter(0)
  %w.i = (f32[128,128], s32[]) while((f32[128,128], s32[]) %arg.ob), condition=%inner_cond, body=%inner_body
  ROOT %out.ob = (f32[128,128], s32[]) copy((f32[128,128], s32[]) %w.i)
}

ENTRY %main (p0: f32[128,128]) -> f32[128,128] {
  %p0 = f32[128,128] parameter(0)
  %c0 = s32[] constant(0)
  %tup0 = (f32[128,128], s32[]) tuple(f32[128,128] %p0, s32[] %c0)
  %w.o = (f32[128,128], s32[]) while((f32[128,128], s32[]) %tup0), condition=%outer_cond, body=%outer_body
  ROOT %res = f32[128,128] get-tuple-element((f32[128,128], s32[]) %w.o), index=0
}
"""

# mixed-dtype typed-inline operands + an untyped operand list
MIXED_DOTS = """\
HloModule mixed

ENTRY %main (a: bf16[64,256], b: f16[256,32]) -> f32[64,32] {
  %a = bf16[64,256] parameter(0)
  %b = f16[256,32] parameter(1)
  %dot.t = f32[64,32] dot(bf16[64,256] %a, f16[256,32] %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %a32 = f32[64,256] convert(bf16[64,256] %a)
  %b32 = f32[256,32] convert(f16[256,32] %b)
  %dot.u = f32[64,32] dot(%a32, %b32), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %sum = f32[64,32] add(f32[64,32] %dot.t, f32[64,32] %dot.u)
}
"""

# quantized wire: u16 gather + f32 all-reduce, annotated trip count
COLLECTIVES = """\
HloModule coll

%loop_cond (arg.c: (u16[256,256], s32[])) -> pred[] {
  %arg.c = (u16[256,256], s32[]) parameter(0)
  %it.c = s32[] get-tuple-element((u16[256,256], s32[]) %arg.c), index=1
  %c2 = s32[] constant(2)
  ROOT %lt.c = pred[] compare(s32[] %it.c, s32[] %c2), direction=LT
}

%loop_body (arg.b: (u16[256,256], s32[])) -> (u16[256,256], s32[]) {
  %arg.b = (u16[256,256], s32[]) parameter(0)
  %q = u16[256,256] get-tuple-element((u16[256,256], s32[]) %arg.b), index=0
  %ag = u16[4,256,256] all-gather(u16[256,256] %q), replica_groups={{0,1,2,3}}, dimensions={0}
  %sl = u16[256,256] slice(u16[4,256,256] %ag), slice={[0:1], [0:256], [0:256]}
  %it.b = s32[] get-tuple-element((u16[256,256], s32[]) %arg.b), index=1
  %c1 = s32[] constant(1)
  %inc.b = s32[] add(s32[] %it.b, s32[] %c1)
  ROOT %tup.b = (u16[256,256], s32[]) tuple(u16[256,256] %sl, s32[] %inc.b)
}

ENTRY %main (p0: u16[256,256], p1: f32[128,128]) -> f32[128,128] {
  %p0 = u16[256,256] parameter(0)
  %c0 = s32[] constant(0)
  %tup0 = (u16[256,256], s32[]) tuple(u16[256,256] %p0, s32[] %c0)
  %w = (u16[256,256], s32[]) while((u16[256,256], s32[]) %tup0), condition=%loop_cond, body=%loop_body, backend_config={"known_trip_count":{"n":"2"}}
  %p1 = f32[128,128] parameter(0)
  ROOT %ar = f32[128,128] all-reduce(f32[128,128] %p1), replica_groups={}, to_apply=%add_comp
}

%add_comp (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %add.c = f32[] add(f32[] %x, f32[] %y)
}
"""


# -- nested while multipliers ------------------------------------------

def test_nested_while_trip_multiplier():
    cen = hloparse.census(NESTED_WHILES)
    # 2 * 128*128 (out) * 128 (contraction) per execution, 3*4 executions
    per = 2.0 * 128 * 128 * 128
    assert cen["flops"] == pytest.approx(12 * per)
    assert cen["dot_flops_by_dtype"] == {"f32xf32": pytest.approx(12 * per)}


def test_nested_while_loops_reported():
    cen = hloparse.census(NESTED_WHILES)
    trips = dict(cen["loops"])
    assert trips["w.o"] == 3 and trips["w.i"] == 4


# -- dot operand dtype classification ----------------------------------

def test_mixed_dtype_typed_and_untyped_operands():
    cen = hloparse.census(MIXED_DOTS)
    per = 2.0 * 64 * 32 * 256
    by = cen["dot_flops_by_dtype"]
    # typed-inline operands read straight off the line ...
    assert by["bf16xf16"] == pytest.approx(per)
    # ... untyped operands resolve through the computation's symbol table
    assert by["f32xf32"] == pytest.approx(per)
    assert cen["flops"] == pytest.approx(sum(by.values()))


def test_dot_flops_by_dtype_sums_to_aggregate():
    for hlo in (NESTED_WHILES, MIXED_DOTS):
        cen = hloparse.census(hlo)
        assert sum(cen["dot_flops_by_dtype"].values()) == pytest.approx(
            cen["flops"])


# -- collective wire dtypes --------------------------------------------

def test_collective_bytes_by_wire_dtype():
    cen = hloparse.census(COLLECTIVES)
    by = cen["collective_bytes_by_dtype"]
    # u16 gather rides the annotated known_trip_count=2 while loop
    assert by["u16"] == pytest.approx(2 * 4 * 256 * 256 * 2)
    assert by["f32"] == pytest.approx(128 * 128 * 4)


def test_collective_bytes_byte_compatible_with_aggregate():
    cen = hloparse.census(COLLECTIVES)
    agg = sum(v["bytes"] for v in cen["collectives"].values())
    assert sum(cen["collective_bytes_by_dtype"].values()) == pytest.approx(
        agg)
    assert cen["collectives"]["all-gather"]["count"] == 2
    assert cen["collectives"]["all-reduce"]["count"] == 1


def test_known_trip_count_annotation_wins():
    cen = hloparse.census(COLLECTIVES)
    assert dict(cen["loops"])["w"] == 2
