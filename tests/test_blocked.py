"""Blocked-engine equivalence suite + precision-plan unit tests.

The flat blocked executor (core/plan.py + core/blocked.py +
kernels/panel.py) must reproduce the tree recursion's precision
assignment: factors match the tree oracle to the ladder's own unit
roundoff across every PAPER_CONFIGS entry, bitwise where the numerics
are deterministic (single-tile problems reduce both engines to the same
leaf call sequence), on multiple-of-leaf and ragged sizes, for
factorizations and multi-RHS solves.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as core
from repro.core.plan import build_plan

RNG = np.random.default_rng(11)


def spd(n, scale=1.0, seed=None):
    rng = np.random.default_rng(seed) if seed is not None else RNG
    m = rng.uniform(-1, 1, (n, n))
    return ((m @ m.T + n * np.eye(n)) * scale).astype(np.float32)


#: factor-equivalence tolerance per the ladder's COARSEST level — both
#: engines round tiles on that level's grid, so their difference is
#: bounded by a small multiple of its unit roundoff.
_TOL = {"f16": 5e-3, "bf16": 4e-2, "int8": 4e-2, "f32": 5e-6, "f64": 1e-12}

#: every paper config that runs without x64
CONFIGS = [k for k in core.PAPER_CONFIGS if "f64" not in k]


def _engines(name):
    cfg_b = core.PAPER_CONFIGS[name]
    assert cfg_b.engine == "blocked"     # blocked is the default engine
    return cfg_b, dataclasses.replace(cfg_b, engine="tree")


@pytest.mark.parametrize("name", CONFIGS)
@pytest.mark.parametrize("n", [384, 1000])
def test_factor_equivalence(name, n):
    """engine="blocked" matches engine="tree" to the ladder's roundoff
    (multi-tile sizes, including a non-multiple-of-leaf one — ragged
    int8 sizes included, now that ``pad_spd`` scales its diagonal tail
    to the matrix's magnitude; see test_tree_survives_padded_int8).
    """
    cfg_b, cfg_t = _engines(name)
    a = spd(n, seed=n)
    lb = np.asarray(core.cholesky(a, cfg_b), np.float64)
    lt = np.asarray(core.cholesky(a, cfg_t), np.float64)
    scale = np.abs(lt).max()
    rel = np.abs(lb - lt).max() / scale
    assert rel < _TOL[cfg_b.levels[0]], (rel, name)
    assert np.abs(np.triu(lb, 1)).max() == 0.0


@pytest.mark.parametrize("name", CONFIGS)
def test_factor_bitwise_single_tile(name):
    """n <= leaf: both engines reduce to the same leaf call sequence —
    storage_rounding makes the numerics deterministic, so bitwise."""
    cfg_b, cfg_t = _engines(name)
    a = spd(cfg_b.leaf, seed=5)
    np.testing.assert_array_equal(np.asarray(core.cholesky(a, cfg_b)),
                                  np.asarray(core.cholesky(a, cfg_t)))


@pytest.mark.parametrize("name", ["pure_f32", "f16_f32", "bf16_f32",
                                  "f16x3_f32", "int8_f32"])
@pytest.mark.parametrize("nrhs", [1, 5])
def test_solve_equivalence_multirhs(name, nrhs):
    """Blocked solves agree with tree solves: both residuals sit at the
    ladder's accuracy and the solutions track each other."""
    n = 900    # pads to 1024 (ragged path, int8 included post-tail-fix)
    cfg_b, cfg_t = _engines(name)
    a = spd(n, seed=3)
    b = (RNG.standard_normal((n, nrhs)) if nrhs > 1
         else RNG.standard_normal(n)).astype(np.float32)
    xb = np.asarray(core.cholesky_solve(a, b, cfg_b), np.float64)
    xt = np.asarray(core.cholesky_solve(a, b, cfg_t), np.float64)
    assert xb.shape == xt.shape == b.shape
    rb = np.abs(a @ xb - b).max() / np.abs(b).max()
    rt = np.abs(a @ xt - b).max() / np.abs(b).max()
    floor = 10 * _TOL[cfg_b.levels[0]]
    assert rb < max(3 * rt, floor), (rb, rt)
    assert np.abs(xb - xt).max() / max(np.abs(xt).max(), 1.0) < floor


def test_blocked_survives_padded_int8():
    """Regression: an int8 ladder on a non-multiple-of-leaf size stays
    finite and accurate under the blocked engine (it stores trailing
    tiles at their own deeper level, so it was immune to the pad-tail
    bug even before the tail fix)."""
    a = spd(384, seed=384)
    l = np.asarray(core.cholesky(a, core.PAPER_CONFIGS["int8_f32"]),
                   np.float64)
    assert np.isfinite(l).all()
    ref = np.linalg.cholesky(a.astype(np.float64))
    assert np.abs(l - ref).max() / np.abs(ref).max() < 4e-2


@pytest.mark.parametrize("name", ["int8_f32", "int8x3_f32"])
def test_tree_survives_padded_int8(name):
    """Regression for the documented tree-oracle bug (ROADMAP): int8
    ladders NaN'd on non-multiple-of-leaf sizes because ``pad_spd``'s
    unit identity tail quantized to zero when it shared a leaf block
    with the matrix's large diagonal (singular trailing block). The
    tail is now scaled to the diagonal's magnitude, so the tree engine
    must stay finite and match the f64 reference on exactly that case."""
    a = spd(384, seed=384)
    cfg = dataclasses.replace(core.PAPER_CONFIGS[name], engine="tree")
    l = np.asarray(core.cholesky(a, cfg), np.float64)
    assert np.isfinite(l).all()
    ref = np.linalg.cholesky(a.astype(np.float64))
    assert np.abs(l - ref).max() / np.abs(ref).max() < 4e-2


def test_pad_spd_tail_tracks_diagonal_magnitude():
    """The padding tail sits at the diagonal's (power-of-two) magnitude
    and pad_factor recovers the exact same scale from the factor."""
    a = spd(300, seed=2) * 64.0
    a_p, n = core.pad_spd(jnp.asarray(a), 128)
    tail = np.asarray(a_p)[range(n, 384), range(n, 384)]
    assert (tail == tail[0]).all() and tail[0] > 1.0
    frac, _ = np.frexp(float(tail[0]))
    assert frac == 0.5                       # exact power of two
    mag = np.abs(np.diagonal(a)).mean()
    assert mag / 2 <= tail[0] <= mag * 2


def test_refine_equivalence():
    """refine_solve converges to working precision under both engines."""
    n = 700
    a = spd(n, seed=17)
    b = RNG.standard_normal((n, 2)).astype(np.float32)
    rcfg = core.RefineConfig(max_sweeps=10, tol=1e-6)
    for name in ("f16_f32", "bf16_f32"):
        cfg_b, cfg_t = _engines(name)
        for cfg in (cfg_b, cfg_t):
            res = core.refine_solve(a, b, cfg, refine=rcfg)
            assert bool(np.asarray(res.converged).all()), name
            assert float(np.asarray(res.residual).max()) < 1e-6


# ---------------------------------------------------------------------------
# precision plan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", CONFIGS)
@pytest.mark.parametrize("ntiles", [1, 4, 7])
def test_plan_levels(name, ntiles):
    cfg = core.PAPER_CONFIGS[name]
    n = ntiles * cfg.leaf
    plan = build_plan(n, cfg)
    T = plan.ntiles
    assert T == ntiles
    # deepest diagonal level == the recursion depth of cfg geometry
    assert max(plan.level(i, i) for i in range(T)) == cfg.depth(n)
    # symmetric lookups, names/quant consistent with the ladder
    for i in range(T):
        for j in range(i + 1):
            assert plan.level(i, j) == plan.level(j, i)
            assert plan.name(i, j) == cfg.name_at(plan.level(i, j))
            assert plan.quant(i, j) == cfg.needs_quant(plan.level(i, j))
            info = plan.tile(i, j)
            assert info.name == plan.name(i, j)
            # storage happens at the TRSM-leaf level: never shallower
            # (= never lower precision) than the compute level
            assert info.store_level >= info.level
    if T > 1:
        # the far corner is separated by the first split: coarsest level
        assert plan.level(T - 1, 0) == 0
        # precision rises toward the diagonal along the first column
        col = [plan.level(i, 0) for i in range(1, T)]
        assert all(a >= b for a, b in zip(col, col[1:]))


def test_plan_tile_census():
    cfg = core.PrecisionConfig(levels=("f16",) * 3 + ("f32",), leaf=128)
    plan = build_plan(8 * 128, cfg)
    counts = plan.level_counts()
    assert sum(counts.values()) == 8 * 9 // 2
    assert set(counts) <= {"f16", "f32"}
    # deeper ladders put the bulk of tiles in low precision (Fig. 10)
    assert plan.lowp_tile_fraction() > 0.5
    d = plan.describe()
    assert "PrecisionPlan" in d and "f16" in d and "tiles" in d


def test_plan_matches_depth_badge_scaling():
    """Bigger n => a larger fraction of tiles at the coarse level (the
    paper's Fig. 10 mechanism, now readable statically off the plan)."""
    cfg = core.PrecisionConfig(levels=("f16", "f32"), leaf=256)
    fracs = [build_plan(n, cfg).lowp_tile_fraction()
             for n in (512, 2048, 8192)]
    assert fracs[0] < fracs[1] < fracs[2], fracs


# ---------------------------------------------------------------------------
# pad_factor / cached-linvs satellites
# ---------------------------------------------------------------------------
def test_pad_factor_matches_padded_cholesky():
    cfg = core.PrecisionConfig(levels=("f32",), leaf=128)
    a = spd(300, seed=9)
    l = core.cholesky(a, cfg)
    lp = core.pad_factor(l, 128)
    assert lp.shape == (384, 384)
    a_p, _ = core.pad_spd(jnp.asarray(a), 128)
    np.testing.assert_array_equal(np.asarray(lp),
                                  np.asarray(core.cholesky(a_p, cfg)))
    # multiple-of-leaf factors pass through untouched
    assert core.pad_factor(lp, 128) is lp


def test_solve_accepts_padded_factor():
    cfg = core.PrecisionConfig(levels=("f32",), leaf=128)
    a = spd(300, seed=9)
    b = RNG.standard_normal((300, 2)).astype(np.float32)
    l = core.cholesky(a, cfg)
    x1 = np.asarray(core.cholesky_solve(a, b, cfg, l=l))
    x2 = np.asarray(core.cholesky_solve(a, b, cfg,
                                        l=core.pad_factor(l, 128)))
    np.testing.assert_array_equal(x1, x2)


def test_solve_with_cached_linvs_matches():
    cfg = core.PrecisionConfig(levels=("bf16", "f32"), leaf=128)
    a = spd(512, seed=13)
    b = RNG.standard_normal((512, 3)).astype(np.float32)
    l = core.cholesky(a, cfg)
    linvs = core.diag_tri_inv(l, cfg)
    assert linvs.shape == (4, 128, 128)
    x1 = np.asarray(core.cholesky_solve(a, b, cfg, l=l))
    x2 = np.asarray(core.cholesky_solve(a, b, cfg, l=l, linvs=linvs))
    np.testing.assert_array_equal(x1, x2)


def test_serve_engine_caches_linvs():
    from repro.serve.engine import SolverEngine
    eng = SolverEngine("bf16_f32", max_sweeps=6)
    a = spd(300, seed=21)
    l, linvs, cached = eng.factor(a, cache_key="k")
    assert not cached and l.shape == (512, 512)   # leaf-padded factor
    assert linvs is not None and linvs.shape[0] == 2
    l2, linvs2, cached2 = eng.factor(a, cache_key="k")
    assert cached2 and l2 is l and linvs2 is linvs


# ---------------------------------------------------------------------------
# dispatch-count regression (the jaxpr the engines trace to)
# ---------------------------------------------------------------------------
def test_blocked_traces_fewer_eqns_than_tree():
    import functools
    cfg_b, cfg_t = _engines("bf16_f32")
    a = jnp.zeros((2048, 2048), jnp.float32)
    nb = len(jax.make_jaxpr(
        functools.partial(core.cholesky, cfg=cfg_b))(a).eqns)
    nt = len(jax.make_jaxpr(
        functools.partial(core.cholesky, cfg=cfg_t))(a).eqns)
    assert nb < nt, (nb, nt)
