"""HLO-census + roofline unit tests: the parser must recover exact FLOPs
through (nested) scans — the thing XLA's cost_analysis undercounts."""
import jax
import jax.numpy as jnp

from repro.launch import hloparse
from repro.launch.mesh import make_mesh


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_cost_analysis_undercounts_scans():
    """Documents WHY hloparse exists."""
    w = jnp.zeros((256, 256), jnp.float32)
    x = jnp.zeros((32, 256), jnp.float32)

    def f(x, w):
        def body(c, _):
            return jnp.dot(c, w), None
        return jax.lax.scan(body, x, None, length=8)[0]

    c = _compile(f, x, w)
    ca = c.cost_analysis()
    if isinstance(ca, list):  # jaxlib < 0.5 returns [dict] per partition
        ca = ca[0]
    xla = ca["flops"]
    ours = hloparse.census(c.as_text())["flops"]
    expect = 2 * 32 * 256 * 256 * 8
    assert xla < expect / 2          # XLA counts the body once
    assert abs(ours - expect) / expect < 1e-6


def test_census_nested_loops():
    w = jnp.zeros((128, 128), jnp.float32)
    x = jnp.zeros((16, 128), jnp.float32)

    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return jnp.dot(c2, w), None
            return jax.lax.scan(inner, c, None, length=3)[0], None
        return jax.lax.scan(outer, x, None, length=5)[0]

    c = _compile(f, x, w)
    r = hloparse.census(c.as_text())
    expect = 2 * 16 * 128 * 128 * 15
    assert abs(r["flops"] - expect) / expect < 1e-6
    trips = sorted(t for _, t in r["loops"])
    assert trips == [3, 5]


def test_census_counts_collectives():
    if jax.device_count() < 2:
        import pytest
        pytest.skip("needs >=2 devices (subprocess runner)")
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = make_mesh((2,), ("x",))
    xs = jax.ShapeDtypeStruct((128, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(x, w):
        return x @ w

    with mesh:
        c = jax.jit(f, in_shardings=(
            NamedSharding(mesh, P(None, "x")),
            NamedSharding(mesh, P("x", None))),
            out_shardings=NamedSharding(mesh, P())).lower(xs, ws).compile()
    r = hloparse.census(c.as_text())
    total_coll = sum(v["bytes"] for v in r["collectives"].values())
    assert total_coll > 0  # contraction over sharded dim => all-reduce


def test_roofline_analyze_terms():
    from benchmarks import roofline
    rec = {
        "arch": "gemma-2b", "shape": "train_4k", "multi_pod": False,
        "n_devices": 256, "n_params": int(2.5e9), "kfac": False,
        "per_device_bytes": 4 * 2**30,
        "census": {"flops": 8.0e13, "hbm_bytes": 1.0e12},
        "collectives": {"all-gather": {"bytes": 5e10, "count": 10}},
    }
    a = roofline.analyze(rec)
    assert abs(a["compute_s"] - 8e13 / 197e12) < 1e-9
    assert abs(a["memory_s"] - 1e12 / 819e9) < 1e-9
    assert abs(a["collective_s"] - 5e10 / 50e9) < 1e-9
    assert a["dominant"] == "memory"
    assert 0 < a["useful_ratio"] < 1.5
