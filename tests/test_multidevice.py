"""Runs the multi-device test files in subprocesses with forced host
devices (the main pytest session keeps the default 1 device, per the
assignment's instruction not to set device-count flags globally).

The distributed-solver suite (tests/test_distributed.py) runs on a
4-device mesh — the serving topology docs/SERVING.md documents — and
its f64-ladder equivalence entries get an extra JAX_ENABLE_X64 pass.
"""
import os
import subprocess
import sys

import pytest


@pytest.mark.parametrize("target,ndev,extra_env", [
    ("tests/test_moe_sharded.py", 8, {}),
    ("tests/test_train.py::test_ef_compression_dp_trainer", 8, {}),
    ("tests/test_elastic.py", 8, {}),
    ("tests/test_dist_solver.py", 8, {}),
    ("tests/test_distributed.py", 4, {}),
    ("tests/test_distributed.py::test_dist_matches_blocked_f64", 4,
     {"JAX_ENABLE_X64": "1"}),
])
def test_multidevice_subprocess(target, ndev, extra_env):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = "src"
    env.update(extra_env)
    r = subprocess.run(
        [sys.executable, "-m", "pytest", target, "-q", "--no-header"],
        env=env, capture_output=True, text=True, timeout=2400,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, f"\n{r.stdout[-3000:]}\n{r.stderr[-2000:]}"
