"""Runs the multi-device test files in a subprocess with 8 forced host
devices (the main pytest session keeps the default 1 device, per the
assignment's instruction not to set device-count flags globally)."""
import os
import subprocess
import sys

import pytest


@pytest.mark.parametrize("target", [
    "tests/test_moe_sharded.py",
    "tests/test_train.py::test_ef_compression_dp_trainer",
    "tests/test_elastic.py",
    "tests/test_dist_solver.py",
])
def test_multidevice_subprocess(target):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-m", "pytest", target, "-q", "--no-header"],
        env=env, capture_output=True, text=True, timeout=1200,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, f"\n{r.stdout[-3000:]}\n{r.stderr[-2000:]}"
