"""TreeSPD packed-storage tests: round trip, pytree-ness, packed
factorization == dense-API factorization, storage-ratio accounting."""
import jax
import jax.numpy as jnp
import numpy as np

import repro.core as core
from repro.core.treematrix import TreeSPD, storage_ratio, tree_potrf_packed

RNG = np.random.default_rng(5)


def spd(n):
    m = RNG.uniform(-1, 1, (n, n))
    return (m @ m.T + n * np.eye(n)).astype(np.float32)


CFG = core.PrecisionConfig(levels=("f16", "f16", "f32"), leaf=128)


def test_roundtrip_matches_storage_rounding():
    a = spd(512)
    t = TreeSPD.from_dense(jnp.asarray(a), CFG)
    back = np.asarray(t.to_dense())
    # lower triangle reproduces a to f16-storage tolerance
    il = np.tril_indices(512, -1)
    assert np.abs(back[il] - a[il]).max() / np.abs(a).max() < 2e-3
    # diagonal leaf tiles are exact (high precision)
    assert np.abs(np.diag(back) - np.diag(a)).max() == 0.0


def test_is_pytree_and_jits():
    a = spd(256)
    t = TreeSPD.from_dense(jnp.asarray(a), CFG)
    leaves = jax.tree.leaves(t)
    assert any(l.dtype == jnp.float16 for l in leaves)

    @jax.jit
    def dense_of(t):
        return t.to_dense()

    np.testing.assert_allclose(np.asarray(dense_of(t)),
                               np.asarray(t.to_dense()))


def test_packed_factorization_matches_dense_api():
    a = spd(512)
    t = TreeSPD.from_dense(jnp.asarray(a), CFG)
    lp = tree_potrf_packed(t, CFG)
    l_packed = np.asarray(lp.to_dense(), np.float64)
    ref = np.linalg.cholesky(a.astype(np.float64))
    rel = np.abs(np.tril(l_packed) - ref).max() / np.abs(ref).max()
    assert rel < 5e-3, rel          # f16-ladder accuracy


def test_storage_ratio():
    cfg = core.PrecisionConfig(levels=("f16", "f16", "f32"), leaf=256)
    r = storage_ratio(65536, cfg)
    # analytic: n^2(1/4*2 + 1/8*2 + 1/8*4)B / 4n^2 B = 0.3125
    assert 0.29 < r < 0.34, r
    r8 = storage_ratio(65536, core.PrecisionConfig(
        levels=("int8", "int8", "f32"), leaf=256))
    assert 0.19 < r8 < 0.26, r8
    t = TreeSPD.from_dense(jnp.asarray(spd(512)),
                           core.PrecisionConfig(levels=("f16", "f32"),
                                                leaf=128))
    assert t.nbytes() < 512 * 512 * 4   # beats dense f32
