"""Unit tests for the unified CI perf gates (tools/perf_gate.py).

The gate module lives outside the package tree (tools/), so it is
loaded by file path. Each gate gets a passing payload and the specific
regressions it exists to catch.
"""
from __future__ import annotations

import importlib.util
import json
import os

_TOOLS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "tools")
_spec = importlib.util.spec_from_file_location(
    "perf_gate", os.path.join(_TOOLS, "perf_gate.py"))
perf_gate = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(perf_gate)


def chol_row(n, speedup=1.4, eb=24, et=41):
    return {"n": n, "ladder": "bf16_f32", "leaf": 256,
            "us_tree": 1000.0, "us_blocked": 1000.0 / speedup,
            "eqns_tree": et, "eqns_blocked": eb,
            "speedup_blocked_vs_tree": speedup}


def dist_row(n, *, compressed=1.1, rel=1e-5, engine=None, source="exact",
             xover=1800, tuned_speedup=None, auto_ok=True):
    engine = engine or ("tree" if n < xover else "blocked")
    if tuned_speedup is None:
        tuned_speedup = 1.0 if engine == "tree" else 1.05
    return {"n": n, "ladder": "bf16_f32", "leaf": 128, "nshards": 4,
            "us_local_tree": 1000.0, "us_local_blocked": 950.0,
            "us_local_tuned": 1000.0 / tuned_speedup,
            "us_comm_f32_gather": 1100.0,
            "us_comm_compressed": 1100.0 / compressed,
            "speedup_blocked_vs_tree": 1.05,
            "speedup_tuned_vs_tree": tuned_speedup,
            "speedup_compressed_vs_f32": compressed,
            "rel_vs_single_device": rel,
            "tuned_engine": engine, "tuned_source": source,
            "tuned_crossover_n": xover, "auto_matches_tuned": auto_ok}


# ---------------------------------------------------------------------------
# cholesky gate
# ---------------------------------------------------------------------------
def test_cholesky_gate_passes_and_catches():
    ok = {"bench": "cholesky_engines",
          "rows": [chol_row(512), chol_row(2048)]}
    assert perf_gate.gate_cholesky(ok) == []
    assert perf_gate.gate_cholesky({"rows": []}) != []
    slow = {"rows": [chol_row(2048, speedup=0.9)]}
    assert any("slower than tree" in e
               for e in perf_gate.gate_cholesky(slow))
    # a small-n loss is tolerated (that is what the tuner is for)
    assert perf_gate.gate_cholesky({"rows": [chol_row(512, 0.9)]}) == []
    eqns = {"rows": [chol_row(512, eb=50, et=41)]}
    assert any("dispatch count" in e for e in perf_gate.gate_cholesky(eqns))


# ---------------------------------------------------------------------------
# dist gate
# ---------------------------------------------------------------------------
def test_dist_gate_passes_and_catches():
    ok = {"bench": "dist_cholesky", "nshards": 4,
          "rows": [dist_row(1024), dist_row(2048)]}
    assert perf_gate.gate_dist(ok) == []
    empty = {"rows": [], "skipped": "needs_4_devices"}
    assert any("skipped" in e for e in perf_gate.gate_dist(empty))
    slow = {"rows": [dist_row(2048, compressed=0.8)]}
    assert any("compressed" in e for e in perf_gate.gate_dist(slow))
    drift = {"rows": [dist_row(1024, rel=0.2)]}
    assert any("single-device" in e for e in perf_gate.gate_dist(drift))


def test_dist_gate_tuned_selection():
    # selection must come from the database, not the default fallback
    fell_back = {"rows": [dist_row(1024, source="default")]}
    assert any("defaults" in e for e in perf_gate.gate_dist(fell_back))
    # rows written before the tuner integration fail loudly
    legacy = {"rows": [{k: v for k, v in dist_row(1024).items()
                        if not k.startswith("tuned")
                        and k != "auto_matches_tuned"
                        and k != "us_local_tuned"
                        and k != "speedup_tuned_vs_tree"}]}
    assert any("tuned_engine" in e for e in perf_gate.gate_dist(legacy))
    # engine must match its side of the measured crossover
    wrong_side = {"rows": [dist_row(1024, engine="blocked")]}
    assert any("expected tree" in e
               for e in perf_gate.gate_dist(wrong_side))
    wrong_above = {"rows": [dist_row(2048, engine="tree",
                                     tuned_speedup=1.0)]}
    assert any("expected blocked" in e
               for e in perf_gate.gate_dist(wrong_above))
    # null crossover = tree everywhere
    assert perf_gate.gate_dist(
        {"rows": [dist_row(4096, engine="tree", xover=None)]}) == []
    # the tuned engine has to actually win (tree side: >= 1.0 exactly)
    losing = {"rows": [dist_row(1024, tuned_speedup=0.97)]}
    assert any("tuned engine loses" in e for e in perf_gate.gate_dist(losing))
    below_floor = {"rows": [dist_row(2048, tuned_speedup=0.9)]}
    assert any("tuned engine loses" in e
               for e in perf_gate.gate_dist(below_floor))
    # auto must trace to the tuned engine's computation
    diverged = {"rows": [dist_row(1024, auto_ok=False)]}
    assert any("auto" in e for e in perf_gate.gate_dist(diverged))


# ---------------------------------------------------------------------------
# schema gate
# ---------------------------------------------------------------------------
def test_schema_gate():
    ok = {"bench": "dist_cholesky", "nshards": 4, "rows": [dist_row(1024)]}
    assert perf_gate.check_schema(ok, "BENCH_dist.json") == []
    missing = {"rows": [dist_row(1024)]}
    assert any("nshards" in e
               for e in perf_gate.check_schema(missing, "BENCH_dist.json"))
    assert any("rows empty" in e for e in perf_gate.check_schema(
        {"bench": "x", "rows": []}, "BENCH_other.json"))
    nan = {"bench": "x", "rows": [{"n": 512, "us_t": float("nan")}]}
    assert any("not finite" in e
               for e in perf_gate.check_schema(nan, "BENCH_other.json"))
    zero = {"bench": "x", "rows": [{"n": 512, "us_t": 0.0}]}
    assert any("not finite" in e
               for e in perf_gate.check_schema(zero, "BENCH_other.json"))
    malformed = {"bench": "x", "rows": [{"us_t": 1.0}]}
    assert any("malformed" in e
               for e in perf_gate.check_schema(malformed, "BENCH_o.json"))


def test_gates_pass_on_committed_artifacts():
    """The repo-root BENCH_*.json artifacts must satisfy their own gates
    (CI regenerates them, but the committed state stays coherent)."""
    root = os.path.dirname(_TOOLS)
    chol = json.load(open(os.path.join(root, "BENCH_cholesky.json")))
    dist = json.load(open(os.path.join(root, "BENCH_dist.json")))
    assert perf_gate.gate_cholesky(chol) == []
    assert perf_gate.gate_dist(dist) == []
    assert perf_gate.check_schema(chol, "BENCH_cholesky.json") == []
    assert perf_gate.check_schema(dist, "BENCH_dist.json") == []


def test_db_gate_on_committed_database():
    root = os.path.dirname(_TOOLS)
    path = os.path.join(root, "src", "repro", "tune", "data", "cpu.json")
    payload = json.load(open(path))
    assert perf_gate.gate_db(payload) == []
    assert perf_gate.gate_db({"version": 1}) != []


# ---------------------------------------------------------------------------
# serve gate (continuous vs window)
# ---------------------------------------------------------------------------
def serve_row(r, *, speedup=1.5, converged=True, n=256):
    return {"name": f"serve_continuous_f16_f32_n{n}_r{r}",
            "us_per_call": 1000.0,
            "derived": f"req_per_s=50.0;speedup_vs_window={speedup:.2f};"
                       f"converged={converged};slots={max(2, r // 2)}"}


def test_serve_gate_passes_and_catches():
    ok = {"smoke": True, "rows": [serve_row(8), serve_row(16)]}
    assert perf_gate.gate_serve(ok) == []
    # continuous losing the race at r>=8 is the regression this exists for
    slow = {"rows": [serve_row(8, speedup=0.8)]}
    assert any("lost to the window" in e for e in perf_gate.gate_serve(slow))
    # a speed win that missed accuracy targets is not a win
    inacc = {"rows": [serve_row(8, converged=False)]}
    assert any("accuracy" in e for e in perf_gate.gate_serve(inacc))
    assert perf_gate.gate_serve({"rows": []}) != []


def test_serve_gate_requires_r8_rows():
    """An artifact with only sub-threshold races must fail loudly — it
    means bench_serve ran without the continuous race."""
    small = {"rows": [serve_row(4),
                      {"name": "serve_window_f16_f32_n256_r8",
                       "us_per_call": 900.0, "derived": "req_per_s=9.0"}]}
    assert any("no serve_continuous" in e
               for e in perf_gate.gate_serve(small))
