"""Training-substrate integration tests: loss goes down with both
optimizers, grad-accum invariance, checkpoint round-trip + elastic
restore, and the int8 error-feedback data-parallel trainer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.compat import shard_map
from repro.launch.mesh import make_mesh
from repro.data import SyntheticLM
from repro.models.common import ModelConfig
from repro.optim import AdamWConfig, TreeNewtonConfig
from repro.train import (TrainConfig, compress, init_state, make_train_step,
                         reshape_for_accum)

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  d_ff=128, vocab=128, n_heads=4, n_kv=2, mlp="swiglu",
                  max_seq=64, remat=False)


def _run(tcfg, steps=30, seed=0):
    data = SyntheticLM(CFG.vocab, batch=8, seq=32, seed=seed)
    state = init_state(jax.random.PRNGKey(seed), CFG, tcfg)
    step = jax.jit(make_train_step(CFG, tcfg))
    losses = []
    for i in range(steps):
        batch = jax.tree.map(jnp.asarray, data.get(i))
        batch = reshape_for_accum(batch, tcfg.accum)
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    return losses, state


def test_adamw_loss_decreases():
    adam = AdamWConfig(lr=1e-2, warmup=5, total_steps=100)
    losses, _ = _run(TrainConfig(optimizer="adamw", adam=adam))
    assert losses[-1] < losses[0] - 0.5, losses[::10]
    assert np.isfinite(losses).all()


def test_tree_newton_loss_decreases():
    adam = AdamWConfig(lr=1e-2, warmup=5, total_steps=100)
    tn = TreeNewtonConfig(adam=adam, block=64, factor_every=5,
                          stats_every=1)
    losses, _ = _run(TrainConfig(optimizer="tree_newton", tree_newton=tn))
    assert losses[-1] < losses[0] - 0.5, losses[::10]
    assert np.isfinite(losses).all()


def test_tree_newton_not_worse_than_adam():
    """The paper-solver optimizer should at least match AdamW here."""
    adam = AdamWConfig(lr=1e-2, warmup=5, total_steps=100)
    la, _ = _run(TrainConfig(optimizer="adamw", adam=adam), steps=40)
    tn = TreeNewtonConfig(adam=adam, block=64, factor_every=5)
    lt, _ = _run(TrainConfig(optimizer="tree_newton", tree_newton=tn),
                 steps=40)
    assert np.mean(lt[-5:]) <= np.mean(la[-5:]) + 0.25


def test_grad_accum_equivalence():
    """accum=2 must match accum=1 on the same global batch (modulo f32
    reduction order)."""
    adam = AdamWConfig(lr=1e-3, warmup=0, total_steps=100)
    t1 = TrainConfig(optimizer="adamw", adam=adam, accum=1)
    t2 = TrainConfig(optimizer="adamw", adam=adam, accum=2)
    data = SyntheticLM(CFG.vocab, batch=8, seq=32, seed=3)
    batch = jax.tree.map(jnp.asarray, data.get(0))
    s1 = init_state(jax.random.PRNGKey(0), CFG, t1)
    s2 = init_state(jax.random.PRNGKey(0), CFG, t2)
    s1, m1 = jax.jit(make_train_step(CFG, t1))(s1, batch)
    s2, m2 = jax.jit(make_train_step(CFG, t2))(
        s2, reshape_for_accum(batch, 2))
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    d = max(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])))
    assert d < 1e-5, d


def test_checkpoint_roundtrip_and_resume(tmp_path):
    adam = AdamWConfig(lr=1e-2, warmup=0, total_steps=100)
    tcfg = TrainConfig(optimizer="adamw", adam=adam)
    data = SyntheticLM(CFG.vocab, batch=8, seq=32, seed=1)
    step = jax.jit(make_train_step(CFG, tcfg))

    state = init_state(jax.random.PRNGKey(1), CFG, tcfg)
    for i in range(5):
        state, _ = step(state, jax.tree.map(jnp.asarray, data.get(i)))
    h = ckpt.save(str(tmp_path), 5, state, blocking=True)
    h.wait()

    # continue 5 more steps from live state
    live = state
    for i in range(5, 10):
        live, ml = step(live, jax.tree.map(jnp.asarray, data.get(i)))

    # restore and replay the same steps — deterministic pipeline =>
    # identical result
    restored, s0 = ckpt.restore(str(tmp_path), state)
    assert s0 == 5
    for i in range(5, 10):
        restored, mr = step(restored,
                            jax.tree.map(jnp.asarray, data.get(i)))
    assert abs(float(ml["loss"]) - float(mr["loss"])) < 1e-5
    d = max(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree.leaves(live["params"]), jax.tree.leaves(restored["params"])))
    assert d < 1e-5


def test_checkpoint_keep_last(tmp_path):
    state = {"x": jnp.arange(4.0)}
    for s in range(6):
        ckpt.save(str(tmp_path), s, state, keep_last=2, blocking=True)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2 and steps[-1] == "step_00000005"
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_ef_compression_dp_trainer():
    """Mini data-parallel trainer with int8+EF gradient all-reduce on 8
    host devices: converges like the uncompressed baseline."""
    if jax.device_count() < 8:
        pytest.skip("needs --xla_force_host_platform_device_count=8 "
                    "(run via tests/conftest multi-device session)")
    from jax.sharding import PartitionSpec as P
    mesh = make_mesh((8,), ("dp",))
    rng = np.random.default_rng(0)
    w_true = rng.standard_normal((16, 4)).astype(np.float32)
    X = rng.standard_normal((64, 16)).astype(np.float32)
    Y = X @ w_true

    def local_step(w, res, x, y, lr):
        res = res[0]                    # [1,16,4] local shard -> [16,4]
        def loss(w):
            return jnp.mean((x @ w - y) ** 2)
        g = jax.grad(loss)(w)
        g, res = compress.ef_allreduce_mean({"w": g}, {"w": res}, "dp")
        return w - lr * g["w"], res["w"][None]

    fn = jax.jit(shard_map(
        local_step, mesh=mesh,
        in_specs=(P(), P("dp"), P("dp"), P("dp"), P()),
        out_specs=(P(), P("dp"))))
    w = jnp.zeros((16, 4))
    res = jnp.zeros((8, 16, 4))         # per-replica EF residual
    lr = jnp.float32(0.05)
    for _ in range(600):    # int8 EF noise slows early progress; err at
        w, res = fn(w, res, X, Y, lr)   # 600 steps is ~9e-3, margin 5x
    err = float(jnp.abs(w - w_true).max())
    assert err < 5e-2, err


def test_kfac_refactor_engines_agree():
    """Satellite of the tuner PR: the K-FAC factor stack now vmaps the
    blocked engine by default — its factors must match the tree path on
    the same damped stats (shared bf16 ladder, so agreement is tight),
    and engine="auto" must produce one of the two."""
    import dataclasses

    from repro.optim import kfac

    cfg = kfac.TreeNewtonConfig()           # block=512, bf16_f32, leaf 128
    rng = np.random.default_rng(0)
    n = cfg.block
    m = rng.uniform(-1, 1, (3, n, n))
    a = (m + m.transpose(0, 2, 1)) / 2
    idx = np.diag_indices(n)
    a[:, idx[0], idx[1]] += n
    a = jnp.asarray(a, jnp.float32)

    def with_engine(eng):
        p = dataclasses.replace(cfg.precision, engine=eng)
        return np.asarray(kfac._refactor(a, dataclasses.replace(
            cfg, precision=p)), np.float64)

    l_blocked = with_engine("blocked")
    l_tree = with_engine("tree")
    scale = np.abs(l_tree).max()
    assert np.abs(l_blocked - l_tree).max() / scale < 1e-4
    l_auto = with_engine("auto")
    assert (np.array_equal(l_auto, l_blocked)
            or np.array_equal(l_auto, l_tree))
    # both reconstruct the damped stats to bf16-ladder accuracy
    damped = np.asarray(kfac._damped(a, cfg), np.float64)
    rec = np.einsum("bij,bkj->bik", l_blocked, l_blocked)
    assert np.abs(rec - damped).max() / np.abs(damped).max() < 4e-2

    # blocks smaller than the leaf stay on the tree base case
    small_cfg = dataclasses.replace(cfg, block=64)
    l_small = np.asarray(kfac._refactor(jnp.asarray(a[:, :64, :64]),
                                        small_cfg), np.float64)
    assert np.isfinite(l_small).all()
