"""Continuous-batching serving: slot loop, shedding, options, metrics.

The load-bearing contract is DETERMINISM: classic IR is column-local
(per-column scaling, residuals and corrections), so a column's refinement
trajectory must be identical whether it runs in a window
(``SolverEngine.solve_batched``) or through the re-entrant slot loop
(``BatchScheduler(continuous=True)``), regardless of co-tenants or when
it joined. Everything else — mid-flight join, retire-once, deadlines,
tiered shedding, the SolveOptions redesign and the metrics layer — is
pinned around that.
"""
from __future__ import annotations

import threading
import warnings

import numpy as np
import pytest

from repro.serve import (BatchScheduler, InMemoryMetrics, MetricsTracker,
                         NullMetrics, SchedulerOverload, ServeFrontend,
                         SolveOptions, SolverEngine)

N = 64


def _spd(n=N, seed=0):
    rng = np.random.default_rng(seed)
    m = rng.standard_normal((n, n))
    return (m @ m.T + n * np.eye(n)).astype(np.float32)


def _rhs(a, seed=0, k=None):
    rng = np.random.default_rng(100 + seed)
    shape = (a.shape[0],) if k is None else (a.shape[0], k)
    return (a @ rng.standard_normal(shape)).astype(np.float32)


@pytest.fixture(scope="module")
def eng():
    return SolverEngine("f16_f32", max_sweeps=8,
                        metrics=InMemoryMetrics())


# ---------------------------------------------------------------------------
# determinism: continuous == window, column for column
# ---------------------------------------------------------------------------
def test_continuous_matches_window_column_for_column(eng):
    """4 mixed-target requests through a 2-slot continuous loop (so two
    of them MUST join mid-flight) vs one windowed stacked call: same x,
    same sweep counts, same per-column residual histories."""
    a = _spd(seed=1)
    bs = [_rhs(a, seed=i) for i in range(4)]
    targets = [3.0, 6.0, 3.0, 6.0]

    xs_w, infos_w = eng.solve_batched(
        a, bs, SolveOptions(target_digits=targets, cache_key="det"))

    sch = BatchScheduler(eng, max_batch=2, continuous=True)
    sch.start()
    futs = [sch.submit_async(a, b, SolveOptions(target_digits=t,
                                                cache_key="det"))
            for b, t in zip(bs, targets)]
    outs = [f.result(timeout=120) for f in futs]
    sch.stop()

    for i, ((x_c, info_c), x_w, info_w) in enumerate(zip(outs, xs_w,
                                                         infos_w)):
        assert np.array_equal(np.asarray(x_c), np.asarray(x_w)), i
        assert info_c.sweeps == info_w.sweeps, i
        assert info_c.converged and info_w.converged, i
        assert info_c.history == info_w.history, i
        assert info_c.residual == pytest.approx(info_w.residual), i


def test_continuous_blockwidth_invariance(eng):
    """A request's result must not depend on the slot-block width it ran
    in (widths >= 2 share the GEMM kernel, so per-column results are
    bitwise equal; width 1 lowers to a GEMV and is out of scope)."""
    a = _spd(seed=2)
    b = _rhs(a, seed=9)
    outs = []
    for slots in (2, 4):
        sch = BatchScheduler(eng, max_batch=slots, continuous=True)
        sch.start()
        fut = sch.submit_async(a, b, SolveOptions(target_digits=6.0,
                                                  cache_key="width"))
        outs.append(fut.result(timeout=120))
        sch.stop()
    (x2, i2), (x4, i4) = outs
    assert np.array_equal(np.asarray(x2), np.asarray(x4))
    assert i2.history == i4.history


# ---------------------------------------------------------------------------
# stepper-level: mid-flight join, retire-once
# ---------------------------------------------------------------------------
def test_midflight_join_preserves_histories(eng):
    """A column joining two sweeps into a stranger's run must follow the
    exact trajectory it has when running alone in the same slot block —
    co-tenancy (who else occupies the block, and when they joined) must
    not perturb a column."""
    a = _spd(seed=3)
    b0, b1 = _rhs(a, seed=0), _rhs(a, seed=1)
    stepper, base_solve, _ = eng.continuous_stepper(a, slots=3,
                                                    cache_key="join")
    tol = 1e-12                       # unreachable: run both to stall

    def prep(b):
        bb = np.asarray(b, np.float32)[:, None]
        return bb, base_solve(bb.astype(stepper.rdtype))

    def solo(b, slot):
        """Reference: the column alone in an otherwise-empty block."""
        bb, x0 = prep(b)
        state = stepper.init()
        state = stepper.join(state, [slot], bb, x0, [tol])
        hist = [float(np.asarray(state.rel)[slot])]
        while stepper.active_mask(state).any():
            state, _ = stepper.step(state)
            hist.append(float(np.asarray(state.rel)[slot]))
        return tuple(hist)

    ref0, ref1 = solo(b0, 0), solo(b1, 1)

    state = stepper.init()
    bb0, x00 = prep(b0)
    state = stepper.join(state, [0], bb0, x00, [tol])
    hist = {0: [float(np.asarray(state.rel)[0])], 1: []}
    for _ in range(2):                # col 0 runs alone for two sweeps
        state, act = stepper.step(state)
        assert act[0] and not act[1]
        hist[0].append(float(np.asarray(state.rel)[0]))
    bb1, x01 = prep(b1)
    state = stepper.join(state, [1], bb1, x01, [tol])   # mid-flight join
    hist[1].append(float(np.asarray(state.rel)[1]))
    while stepper.active_mask(state).any():
        state, act = stepper.step(state)
        rel = np.asarray(state.rel)
        for s in (0, 1):
            if act[s]:
                hist[s].append(float(rel[s]))
    assert tuple(hist[0]) == ref0
    assert tuple(hist[1]) == ref1


def test_retired_slots_never_recompute(eng):
    """A retired slot is inert: cleared, excluded from the active mask,
    and untouched by later sweeps until a new column joins it."""
    a = _spd(seed=4)
    stepper, base_solve, _ = eng.continuous_stepper(a, slots=2,
                                                    cache_key="retire")
    bb = np.asarray(_rhs(a, seed=0), np.float32)[:, None]
    state = stepper.init()
    state = stepper.join(state, [0], bb,
                         base_solve(bb.astype(stepper.rdtype)), [1e-6])
    while not stepper.done_mask(state).any():
        state, _ = stepper.step(state)
    state, [(x, relres, sweeps, conv)] = stepper.retire(state, [0])
    assert conv and relres <= 1e-6 and sweeps >= 1
    assert not np.asarray(state.occ)[0]
    assert np.asarray(state.its)[0] == 0
    assert not np.asarray(state.x[:, 0]).any()    # cleared
    # join a second column into slot 1 and sweep: slot 0 must stay inert
    b2 = np.asarray(_rhs(a, seed=1), np.float32)[:, None]
    state = stepper.join(state, [1], b2,
                         base_solve(b2.astype(stepper.rdtype)), [1e-6])
    state, act = stepper.step(state)
    assert not act[0] and act[1]
    assert np.asarray(state.its)[0] == 0
    assert not np.asarray(state.x[:, 0]).any()


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------
def test_deadline_expiry_returns_best_so_far(eng):
    """deadline_ms=0 expires before the first sweep: the request comes
    back immediately with its initial iterate, marked, not converged."""
    a = _spd(seed=5)
    b = _rhs(a, seed=0)
    sch = BatchScheduler(eng, max_batch=2, continuous=True)
    sch.start()
    fut = sch.submit_async(a, b, SolveOptions(
        target_digits=6.0, deadline_ms=0.0, cache_key="dead"))
    x, info = fut.result(timeout=120)
    sch.stop()
    assert info.deadline_expired and not info.converged
    assert info.sweeps == 0
    assert len(info.history[0]) == 1          # rel0 only, no sweeps ran
    assert info.residual == pytest.approx(info.history[0][0])
    # best-so-far == the base (factored) solve's initial iterate
    stepper, base_solve, _ = eng.continuous_stepper(a, slots=2,
                                                    cache_key="dead")
    x0 = base_solve(np.asarray(b, np.float32)[:, None].astype(
        stepper.rdtype))
    assert np.array_equal(np.asarray(x), np.asarray(x0)[:, 0])


# ---------------------------------------------------------------------------
# tiered shedding (frontend)
# ---------------------------------------------------------------------------
class _StubScheduler:
    def __init__(self):
        self.metrics = InMemoryMetrics()
        self.depth = 0
        self.seen: list[SolveOptions] = []

    def pending_cols(self):
        return self.depth

    def submit_async(self, a, b, options):
        self.seen.append(options)
        return "future"


def test_shedding_tier_boundaries():
    sch = _StubScheduler()
    fe = ServeFrontend(sch, soft_pending=2, hard_pending=4,
                       degraded_digits=4.0)
    # tier 0: below soft — request passes through untouched
    sch.depth = 1
    fe.submit(None, None, SolveOptions(target_digits=7.0))
    assert sch.seen[-1].target_digits == 7.0
    assert sch.seen[-1].shed_tier == 0
    # tier 1: [soft, hard) — degrade the target, stamp the tier
    for depth in (2, 3):
        sch.depth = depth
        fe.submit(None, None, SolveOptions(target_digits=7.0))
        assert sch.seen[-1].target_digits == 4.0
        assert sch.seen[-1].shed_tier == 1
    # a request already below the degraded floor keeps its own target
    fe.submit(None, None, SolveOptions(target_digits=3.0))
    assert sch.seen[-1].target_digits == 3.0
    # tier 2: at/above hard — reject
    sch.depth = 4
    with pytest.raises(SchedulerOverload):
        fe.submit(None, None, SolveOptions(target_digits=7.0))
    m = sch.metrics
    assert m.counter("frontend.shed", tier=1) == 3
    assert m.counter("frontend.shed", tier=2) == 1
    assert m.counter("frontend.requests") == 5


def test_frontend_end_to_end_degrades(eng):
    """Against a real continuous scheduler: a backlogged queue degrades
    the admitted request and its SolveInfo says so."""
    a = _spd(seed=6)
    sch = BatchScheduler(eng, max_batch=2, continuous=True)
    fe = ServeFrontend(sch, soft_pending=1, hard_pending=64)
    sch.start()
    opts = SolveOptions(target_digits=7.0, cache_key="fe")
    futs = [fe.submit(a, _rhs(a, seed=i), opts) for i in range(6)]
    outs = [f.result(timeout=120) for f in futs]
    sch.stop()
    tiers = [info.shed_tier for _, info in outs]
    assert tiers[0] == 0
    assert 1 in tiers                 # backlog built up -> some degraded
    for _, info in outs:
        if info.shed_tier == 1:
            assert info.target_digits == pytest.approx(4.0)
            assert info.converged


# ---------------------------------------------------------------------------
# stop() vs submit race
# ---------------------------------------------------------------------------
def test_stop_after_submit_completes_or_raises(eng):
    """A submission racing stop() must either resolve its future or
    raise at submission — never hang or vanish (the silent-drop bug)."""
    a = _spd(seed=7)
    opts = SolveOptions(target_digits=3.0, cache_key="race")
    for round_ in range(5):
        sch = BatchScheduler(eng, max_batch=4, continuous=True)
        sch.start()
        futs, rejected = [], []

        def submitter():
            for i in range(4):
                try:
                    futs.append(sch.submit_async(a, _rhs(a, seed=i), opts))
                except (RuntimeError, AssertionError):
                    # stop won the race: refused loudly, never dropped
                    rejected.append(i)
                    break

        t = threading.Thread(target=submitter)
        t.start()
        sch.stop()
        t.join()
        for f in futs:                    # accepted => must resolve
            x, info = f.result(timeout=120)
            assert info.converged
        assert len(futs) + len(rejected) >= 1


def test_submit_async_raises_while_stopping(eng):
    """Deterministic half of the race: once the stop flag is up, new
    submissions are refused loudly instead of queued into the void."""
    a = _spd(seed=8)
    sch = BatchScheduler(eng, max_batch=2, continuous=True)
    sch.start()
    with sch._cv:
        sch._stop_flag = True             # worker not yet exited
        with pytest.raises(RuntimeError, match="stopping"):
            sch.submit_async(a, _rhs(a), SolveOptions(cache_key="x"))
        sch._stop_flag = False
    sch.stop()


# ---------------------------------------------------------------------------
# SolveOptions redesign: deprecated aliases
# ---------------------------------------------------------------------------
def test_deprecated_kwargs_warn_and_work(eng):
    a = _spd(seed=9)
    b = _rhs(a)
    with pytest.warns(DeprecationWarning, match="SolveOptions"):
        x_old, info_old = eng.solve(a, b, target_digits=5.0,
                                    cache_key="dep")
    with warnings.catch_warnings():
        warnings.simplefilter("error")    # options path must be silent
        x_new, info_new = eng.solve(a, b, SolveOptions(
            target_digits=5.0, cache_key="dep"))
    assert np.array_equal(np.asarray(x_old), np.asarray(x_new))
    assert info_old.sweeps == info_new.sweeps

    sch = BatchScheduler(eng, max_batch=4)
    with pytest.warns(DeprecationWarning):
        rid = sch.submit(a, b, target_digits=5.0, cache_key="dep")
    out = sch.drain()
    assert out[rid][1].converged


def test_unknown_kwarg_raises_typeerror(eng):
    a = _spd(seed=9)
    with pytest.raises(TypeError, match="SolveOptions"):
        eng.solve(a, _rhs(a), targets_digit=5.0)     # typo'd name


def test_options_validation():
    with pytest.raises(AssertionError):
        SolveOptions(method="qr")
    with pytest.raises(AssertionError):
        SolveOptions(shed_tier=3)
    with pytest.raises(AssertionError):
        SolveOptions(deadline_ms=-1.0)


# ---------------------------------------------------------------------------
# metrics layer
# ---------------------------------------------------------------------------
def test_metrics_protocol_and_emission():
    assert isinstance(InMemoryMetrics(), MetricsTracker)
    assert isinstance(NullMetrics(), MetricsTracker)

    a = _spd(seed=10)
    mt = InMemoryMetrics()
    eng2 = SolverEngine("f16_f32", max_sweeps=8, metrics=mt)
    sch = BatchScheduler(eng2, max_batch=2, continuous=True)
    assert sch.metrics is mt              # tracker chains down the stack
    sch.start()
    futs = [sch.submit_async(a, _rhs(a, seed=i),
                             SolveOptions(target_digits=4.0,
                                          cache_key="m"))
            for i in range(3)]
    for f in futs:
        f.result(timeout=120)
    sch.stop()
    snap = mt.snapshot()
    c = snap["counters"]
    assert c["scheduler.requests"] == 3
    assert c["engine.factor_cache_miss"] >= 1
    assert c["scheduler.sweeps"] >= 1
    assert snap["observations"]["scheduler.queue_ms"]["count"] == 3
    assert 0 < snap["gauges"]["scheduler.slot_occupancy"] <= 1.0
    assert any(k.startswith("scheduler.requests") for k in snap["rates"])
