"""Flash-attention Pallas kernel vs the scan-based oracle
(models/attention._chunked_causal) and a naive softmax reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash import flash_attention, flash_attention_bshd

RNG = np.random.default_rng(11)


def naive_causal(q, k, v):
    """q: [H,S,hd]; k/v: [KV,T,hd]."""
    H, S, hd = q.shape
    KV = k.shape[0]
    G = H // KV
    kr = jnp.repeat(k, G, axis=0)
    vr = jnp.repeat(v, G, axis=0)
    s = jnp.einsum("hsd,htd->hst", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * hd ** -0.5
    T = k.shape[1]
    mask = jnp.arange(S)[:, None] >= jnp.arange(T)[None, :]
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hst,htd->hsd", p, vr.astype(jnp.float32))


@pytest.mark.parametrize("H,KV,S,hd", [(4, 4, 256, 64), (8, 2, 256, 128),
                                       (4, 1, 300, 64), (2, 2, 512, 32)])
def test_flash_matches_naive(H, KV, S, hd):
    q = jnp.asarray(RNG.standard_normal((H, S, hd)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((KV, S, hd)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((KV, S, hd)), jnp.float32)
    got = flash_attention(q, k, v, bq=128, bk=128, interpret=True)
    want = naive_causal(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_bf16():
    q = jnp.asarray(RNG.standard_normal((4, 256, 64)), jnp.bfloat16)
    k = jnp.asarray(RNG.standard_normal((2, 256, 64)), jnp.bfloat16)
    v = jnp.asarray(RNG.standard_normal((2, 256, 64)), jnp.bfloat16)
    got = flash_attention(q, k, v, bq=128, bk=128, interpret=True)
    want = naive_causal(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), rtol=2e-2, atol=2e-2)


def test_flash_matches_model_oracle():
    """Against the scan-based online-softmax the models actually use."""
    from repro.models.attention import _chunked_causal
    B, S, KV, G, hd = 2, 256, 2, 2, 64
    q = jnp.asarray(RNG.standard_normal((B, S, KV, G, hd)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, KV, hd)), jnp.float32)
    want = _chunked_causal(q, k, v, q_pos0=0, chunk=128)   # [B,S,KV,G,hd]
    qf = q.reshape(B, S, KV * G, hd)
    got = flash_attention_bshd(qf, k, v, bq=128, bk=128, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want.reshape(B, S, KV * G, hd)),
        rtol=2e-4, atol=2e-4)
