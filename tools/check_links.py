#!/usr/bin/env python
"""Fail on broken intra-repo links in README.md and docs/*.md.

Checks every markdown link/image target that is not an external URL:

* the referenced file must exist (relative to the file containing the
  link, or to the repo root if it starts with ``/``),
* a ``#fragment`` on a markdown target must match a heading in the
  referenced file (GitHub anchor slug rules, simplified).

Run from anywhere: ``python tools/check_links.py``. CI runs it in the
lint job so a renamed doc or section can't leave dangling references —
the repo's docstrings point at docs/ARCHITECTURE.md sections, so those
anchors are load-bearing.
"""
from __future__ import annotations

import glob
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
_EXTERNAL = ("http://", "https://", "mailto:")


def _slug(heading: str) -> str:
    """GitHub-style anchor slug (simplified: enough for our docs)."""
    h = re.sub(r"[`*_]", "", heading.strip().lower())
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def _anchors(path: str) -> set[str]:
    with open(path, encoding="utf-8") as f:
        return {_slug(m.group(1)) for m in _HEADING.finditer(f.read())}


def check(files: list[str]) -> list[str]:
    errors = []
    for md in files:
        base = os.path.dirname(md)
        with open(md, encoding="utf-8") as f:
            text = f.read()
        for m in _LINK.finditer(text):
            target = m.group(1)
            if target.startswith(_EXTERNAL):
                continue
            target, _, frag = target.partition("#")
            rel = os.path.relpath(md, ROOT)
            if not target:               # same-file fragment
                if frag and _slug(frag) not in _anchors(md):
                    errors.append(f"{rel}: missing anchor #{frag}")
                continue
            dest = (os.path.join(ROOT, target.lstrip("/"))
                    if target.startswith("/") else os.path.join(base, target))
            dest = os.path.normpath(dest)
            if not os.path.exists(dest):
                errors.append(f"{rel}: broken link -> {target}")
            elif frag and dest.endswith(".md") and \
                    _slug(frag) not in _anchors(dest):
                errors.append(f"{rel}: missing anchor {target}#{frag}")
    return errors


def main() -> int:
    files = [os.path.join(ROOT, "README.md")] + sorted(
        glob.glob(os.path.join(ROOT, "docs", "*.md")))
    files = [f for f in files if os.path.exists(f)]
    errors = check(files)
    for e in errors:
        print(f"BROKEN: {e}")
    print(f"checked {len(files)} files: "
          f"{'FAIL' if errors else 'OK'} ({len(errors)} broken)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
