#!/usr/bin/env python3
"""Unified CI perf gates over the benchmark JSON artifacts (stdlib-only).

Replaces the inline heredoc gates that used to live in
.github/workflows/ci.yml with one importable, unit-tested module
(tests/test_perf_gate.py). Subcommands:

  cholesky   BENCH_cholesky.json — the flat blocked engine exists to
             beat the recursion: slower than the tree at n >= 2048, or
             no dispatch-count reduction, is a regression.
  dist       BENCH_dist.json — plan-compressed gathers must not lose to
             f32 gathers at n >= 2048 (5% timer-noise allowance), the
             distributed factor must agree with the single-device
             engine, and the tuned engine selection (repro.tune) must
             come from the committed database and win its side of the
             measured crossover.
  schema     any BENCH_*.json — required keys, non-empty rows, finite
             positive timings. Run over every artifact so a bench that
             silently wrote garbage fails loudly.
  db         a tuning-database JSON — schema validation via
             repro.tune.db.validate_db (the one non-stdlib import,
             itself dependency-free).
  serve      bench-serve.json — continuous batching must sustain req/s
             >= the windowed scheduler on the staggered mixed-target
             race at r >= 8, with every request converged (the
             continuous-batching acceptance gate).

Every gate is a function returning a list of error strings (empty =
pass); the CLI prints them and exits non-zero if any gate failed.
"""
from __future__ import annotations

import argparse
import glob
import json
import math
import os
import re
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: minimum top-level keys per BENCH artifact (schema gate)
REQUIRED_KEYS = {
    "BENCH_cholesky.json": ("bench", "rows"),
    "BENCH_dist.json": ("bench", "nshards", "rows"),
}
DEFAULT_KEYS = ("bench", "rows")

#: speedup floors (1.0 = must win; 0.95 = 5% timer-noise allowance)
MIN_BLOCKED_VS_TREE = 1.0       # single-device, n >= 2048
MIN_COMPRESSED_VS_F32 = 0.95    # distributed collectives, n >= 2048
MIN_TUNED_ABOVE_XOVER = 0.95    # tuned engine at/above the crossover
MAX_REL_VS_SINGLE = 5e-2        # distributed-vs-single-device agreement
MIN_CONTINUOUS_VS_WINDOW = 1.0  # staggered req/s race, r >= 8


def _load(path):
    with open(path) as f:
        return json.load(f)


def _finite_pos(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool) \
        and math.isfinite(v) and v > 0


# ---------------------------------------------------------------------------
# gates
# ---------------------------------------------------------------------------
def gate_cholesky(payload) -> list[str]:
    """Blocked-vs-tree single-device gate (BENCH_cholesky.json)."""
    rows = payload.get("rows", [])
    if not rows:
        return ["BENCH_cholesky.json has no rows"]
    errs = []
    for r in rows:
        if r["n"] >= 2048 \
                and r["speedup_blocked_vs_tree"] < MIN_BLOCKED_VS_TREE:
            errs.append(f"blocked slower than tree at n={r['n']}: "
                        f"{r['speedup_blocked_vs_tree']}")
        if r["eqns_blocked"] >= r["eqns_tree"]:
            errs.append(f"dispatch count not reduced at n={r['n']}: "
                        f"blocked={r['eqns_blocked']} tree={r['eqns_tree']}")
    return errs


def gate_dist(payload) -> list[str]:
    """Distributed collectives + tuned-selection gate (BENCH_dist.json)."""
    rows = payload.get("rows", [])
    if not rows:
        skip = payload.get("skipped")
        return [f"BENCH_dist.json has no rows"
                + (f" (bench skipped: {skip})" if skip else "")]
    errs = []
    for r in rows:
        n = r["n"]
        if n >= 2048 \
                and r["speedup_compressed_vs_f32"] < MIN_COMPRESSED_VS_F32:
            errs.append(f"compressed collectives slower than f32 at n={n}: "
                        f"{r['speedup_compressed_vs_f32']}")
        if r["rel_vs_single_device"] > MAX_REL_VS_SINGLE:
            errs.append(f"dist far from single-device engine at n={n}: "
                        f"rel={r['rel_vs_single_device']}")
        # -- tuned selection (rows written by bench_dist since the tuner) --
        if "tuned_engine" not in r:
            errs.append(f"row n={n} has no tuned_engine — bench_dist ran "
                        "without the tuning integration")
            continue
        if r["tuned_source"] == "default":
            errs.append(f"tuned selection at n={n} fell back to defaults "
                        "(committed tuning DB missing or not consulted)")
        if not r.get("auto_matches_tuned", False):
            errs.append(f"engine='auto' traces a different computation "
                        f"than the tuned engine at n={n}")
        xover = r.get("tuned_crossover_n")
        want = "tree" if (xover is None or n < xover) else "blocked"
        if r["tuned_engine"] != want:
            errs.append(f"tuned engine at n={n} is {r['tuned_engine']}, "
                        f"expected {want} (crossover_n={xover})")
        floor = 1.0 if want == "tree" else MIN_TUNED_ABOVE_XOVER
        if r["speedup_tuned_vs_tree"] < floor:
            errs.append(f"tuned engine loses at n={n}: "
                        f"speedup_tuned_vs_tree="
                        f"{r['speedup_tuned_vs_tree']} < {floor}")
    return errs


_CONT_ROW = re.compile(r"^serve_continuous_.+_r(\d+)$")


def _derived(row) -> dict:
    """Parse a bench row's ``k=v;k=v`` derived string into a dict."""
    out = {}
    for part in str(row.get("derived", "")).split(";"):
        if "=" in part:
            k, v = part.split("=", 1)
            out[k] = v
    return out


def gate_serve(payload) -> list[str]:
    """Continuous-batching gate (bench-serve.json).

    Every ``serve_continuous_*_rR`` row with R >= 8 (the staggered
    mixed-target race) must carry ``speedup_vs_window >= 1.0`` and
    ``converged=True``; an artifact with no such rows fails — it means
    bench_serve ran without the continuous race.
    """
    rows = payload.get("rows", [])
    if not rows:
        return ["bench-serve.json has no rows"]
    errs, gated = [], 0
    for row in rows:
        m = _CONT_ROW.match(str(row.get("name", "")))
        if not m or int(m.group(1)) < 8:
            continue
        gated += 1
        d = _derived(row)
        try:
            speedup = float(d.get("speedup_vs_window", "nan"))
        except ValueError:
            speedup = float("nan")
        if not speedup >= MIN_CONTINUOUS_VS_WINDOW:
            errs.append(
                f"{row['name']}: continuous batching lost to the window "
                f"scheduler (speedup_vs_window="
                f"{d.get('speedup_vs_window')!r} "
                f"< {MIN_CONTINUOUS_VS_WINDOW})")
        if d.get("converged") != "True":
            errs.append(f"{row['name']}: accuracy targets not met "
                        f"(converged={d.get('converged')!r})")
    if not gated:
        errs.append("no serve_continuous_*_r>=8 rows found — bench_serve "
                    "ran without the continuous race")
    return errs


def check_schema(payload, name) -> list[str]:
    """Structural check for one BENCH_*.json artifact."""
    errs = []
    for k in REQUIRED_KEYS.get(name, DEFAULT_KEYS):
        if k not in payload:
            errs.append(f"{name}: missing top-level key {k!r}")
    rows = payload.get("rows")
    if not isinstance(rows, list) or not rows:
        skip = payload.get("skipped") if isinstance(payload, dict) else None
        errs.append(f"{name}: rows empty or not a list"
                    + (f" (bench skipped: {skip})" if skip else ""))
        return errs
    for i, r in enumerate(rows):
        if not isinstance(r, dict) or "n" not in r:
            errs.append(f"{name}: row {i} malformed (no 'n'): {r!r}")
            continue
        for k, v in r.items():
            if k.startswith("us_") and not _finite_pos(v):
                errs.append(f"{name}: row n={r['n']} timing {k}={v!r} "
                            "not finite-positive")
    return errs


def gate_audit(payload) -> list[str]:
    """Precision-audit report gate: schema-valid AND zero errors.

    The audit CLI already exits nonzero on errors; this gate re-checks
    the uploaded JSON artifact so a truncated or stale report cannot
    pass CI on exit code alone."""
    src = os.path.join(_ROOT, "src")
    if src not in sys.path:
        sys.path.insert(0, src)
    from repro.audit.report import validate_report
    errs = validate_report(payload)
    if errs:
        return errs
    n_err = payload["summary"]["errors"]
    if n_err:
        rules = sorted({v["rule"] for v in payload["violations"]
                        if v.get("severity", "error") == "error"})
        errs.append(f"audit report carries {n_err} error(s): {rules}")
    return errs


def gate_db(payload) -> list[str]:
    """Tuning-database schema validation (delegates to repro.tune.db)."""
    src = os.path.join(_ROOT, "src")
    if src not in sys.path:
        sys.path.insert(0, src)
    from repro.tune.db import validate_db
    return validate_db(payload)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("gate",
                    choices=("cholesky", "dist", "schema", "db", "audit",
                             "serve"))
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="artifact path(s); default: the repo-root "
                         "BENCH_* file(s) for the gate")
    args = ap.parse_args(argv)

    if args.gate == "schema":
        paths = ([args.json] if args.json
                 else sorted(glob.glob(os.path.join(_ROOT, "BENCH_*.json"))))
        if not paths:
            print("schema gate: no BENCH_*.json artifacts found")
            return 1
        errs = []
        for p in paths:
            errs += check_schema(_load(p), os.path.basename(p))
            print(f"schema checked: {os.path.basename(p)}")
    elif args.gate == "db":
        if not args.json:
            ap.error("db gate needs --json <tuning-db.json>")
        errs = gate_db(_load(args.json))
    elif args.gate == "audit":
        if not args.json:
            ap.error("audit gate needs --json <audit-report.json>")
        errs = gate_audit(_load(args.json))
        if not errs:
            s = _load(args.json)["summary"]
            print(f"audit gate OK: {s['checks']} checks, "
                  f"{s['warns']} warnings")
    elif args.gate == "serve":
        payload = _load(args.json
                        or os.path.join(_ROOT, "bench-serve.json"))
        errs = gate_serve(payload)
        if not errs:
            rows = [(r["name"], _derived(r).get("speedup_vs_window"))
                    for r in payload["rows"]
                    if _CONT_ROW.match(str(r.get("name", "")))]
            print(f"serve gate OK: {rows}")
    else:
        default = os.path.join(_ROOT, f"BENCH_{args.gate}.json")
        payload = _load(args.json or default)
        gate = gate_cholesky if args.gate == "cholesky" else gate_dist
        errs = gate(payload)
        if not errs:
            key = ("speedup_blocked_vs_tree" if args.gate == "cholesky"
                   else "speedup_compressed_vs_f32")
            print(f"{args.gate} gate OK:",
                  [(r["n"], r[key]) for r in payload["rows"]])

    for e in errs:
        print(f"PERF GATE FAIL: {e}", file=sys.stderr)
    return 1 if errs else 0


if __name__ == "__main__":
    raise SystemExit(main())
