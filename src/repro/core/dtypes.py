"""One dtype-name -> byte-width table for the whole repo (stdlib-only).

Three copies of this table used to exist — ``core/census.py:_BYTES``
(ladder names, no f8), ``launch/hloparse.py:_DTYPE_BYTES`` (HLO shape
names) and the implicit widths in the quantized collectives — and they
had already drifted (census lacked the f8 variants).  This module is the
single source of truth; the old names are re-exported where they were.

Two alphabets share the table:

* **ladder names** — the ``repro.core.precision`` alphabet (``int8``,
  ``f16``, ``bf16``, ``f32``, ``f64``) plus the f8 variants the paper's
  ladder may grow into.
* **HLO shape names** — what ``compiled.as_text()`` prints inside shape
  brackets (``f32[4,4]``, ``u16[...]``, ``pred[]``...).

No jax import here: ``tools/`` and the audit lint pack consume this from
stdlib-only contexts.
"""
from __future__ import annotations

#: canonical dtype name -> bytes per element (both alphabets merged)
BYTES = {
    # ladder / jax-style names
    "int8": 1, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "f8e4m3": 1, "f8e5m2": 1,
    # HLO shape-string names
    "f8e4m3fn": 1, "f8e4m3fnuz": 1, "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

#: ladder-name subset (what :mod:`repro.core.census` prices)
LADDER_BYTES = {k: BYTES[k] for k in
                ("int8", "f16", "bf16", "f32", "f64", "f8e4m3", "f8e5m2")}

#: ladder name -> HLO dtype its wire/container representation uses.
#: 16-bit floats cross collectives bitcast to u16 (see
#: ``core/distributed._gather_panel``); int8 rides as s8; wide floats go
#: as themselves.  The HLO-side auditor keys collective bytes on these.
WIRE_DTYPE = {"int8": "s8", "f16": "u16", "bf16": "u16",
              "f8e4m3": "u8", "f8e5m2": "u8", "f32": "f32", "f64": "f64"}


#: numpy dtype name -> HLO shape-string name (what ``compiled.as_text()``
#: prints); the auditor maps traced avals onto HLO census keys with this.
NP_TO_HLO = {"float64": "f64", "float32": "f32", "float16": "f16",
             "bfloat16": "bf16", "float8_e4m3fn": "f8e4m3fn",
             "float8_e5m2": "f8e5m2", "int64": "s64", "uint64": "u64",
             "int32": "s32", "uint32": "u32", "int16": "s16",
             "uint16": "u16", "int8": "s8", "uint8": "u8", "bool": "pred",
             "complex64": "c64", "complex128": "c128"}


def bytes_of(name: str) -> int:
    """Byte width of a dtype name from either alphabet (KeyError if
    unknown — an unknown dtype in a census is a parse bug, not 0 bytes)."""
    return BYTES[name]


def shape_regex_alternation() -> str:
    """``|``-joined dtype names for HLO shape regexes, longest first so
    ``f8e4m3fn`` wins over its ``f8e4m3`` prefix."""
    return "|".join(sorted(BYTES, key=len, reverse=True))
