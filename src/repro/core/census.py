"""Structural census of the recursion tree (no arrays, shapes only).

Mirrors the tree recursion and counts, per precision level:
  * GEMM FLOPs (the MXU-eligible work the recursion exposes),
  * leaf FLOPs (POTRF / TRSM / SYRK leaves),
  * bytes touched per GEMM operand at its storage dtype.

This is what backs the paper's structural claims on CPU: Fig. 10's
"deeper recursion => larger low-precision FLOP fraction" and the derived
MXU throughput model in benchmarks/bench_cholesky.py (real TFLOP/s cannot
be measured in this container; see docs/ARCHITECTURE.md, "Census and
roofline").
"""
from __future__ import annotations

import collections
import dataclasses

from repro.core.dtypes import BYTES as _BYTES  # noqa: F401  (re-export)
from repro.core.precision import PEAK_FLOPS, PrecisionConfig


@dataclasses.dataclass
class Census:
    gemm_flops: dict         # level name -> flops
    leaf_flops: dict         # level name -> flops
    gemm_bytes: dict         # level name -> bytes moved (operands + out)
    leaf_count: int = 0
    gemm_count: int = 0

    @property
    def total_flops(self):
        return sum(self.gemm_flops.values()) + sum(self.leaf_flops.values())

    @property
    def gemm_fraction(self):
        t = self.total_flops
        return sum(self.gemm_flops.values()) / t if t else 0.0

    def lowp_fraction(self, names=("f16", "bf16")):
        t = self.total_flops
        f = sum(v for k, v in self.gemm_flops.items() if k in names)
        return f / t if t else 0.0

    def model_time_s(self, peak=PEAK_FLOPS):
        """MXU throughput model: sum over levels of flops/peak(level)."""
        t = 0.0
        for k, v in self.gemm_flops.items():
            t += v / peak[k]
        for k, v in self.leaf_flops.items():
            t += v / peak[k]
        return t


def _new():
    return Census(gemm_flops=collections.defaultdict(float),
                  leaf_flops=collections.defaultdict(float),
                  gemm_bytes=collections.defaultdict(float))


def _gemm(c: Census, name: str, m, n, k):
    c.gemm_flops[name] += 2.0 * m * n * k
    c.gemm_bytes[name] += _BYTES[name] * (m * k + k * n) + 4 * m * n
    c.gemm_count += 1


def census_potrf(n: int, cfg: PrecisionConfig, c: Census | None = None,
                 level: int = 0) -> Census:
    c = c if c is not None else _new()
    if n <= cfg.leaf:
        c.leaf_flops[cfg.name_at(level)] += n ** 3 / 3.0
        c.leaf_count += 1
        return c
    n1 = cfg.split(n)
    n2 = n - n1
    census_potrf(n1, cfg, c, level + 1)
    census_trsm(n2, n1, cfg, c, level)
    census_syrk(n2, n1, cfg, c, level)
    census_potrf(n2, cfg, c, level + 1)
    return c


def census_trsm(m: int, n: int, cfg: PrecisionConfig,
                c: Census | None = None, level: int = 0) -> Census:
    c = c if c is not None else _new()
    if n <= cfg.leaf:
        c.leaf_flops[cfg.name_at(level)] += float(m) * n * n
        c.leaf_count += 1
        return c
    n1 = cfg.split(n)
    n2 = n - n1
    census_trsm(m, n1, cfg, c, level + 1)
    _gemm(c, cfg.name_at(level), m, n2, n1)
    census_trsm(m, n2, cfg, c, level + 1)
    return c


def census_syrk(n: int, k: int, cfg: PrecisionConfig,
                c: Census | None = None, level: int = 0) -> Census:
    c = c if c is not None else _new()
    if n <= cfg.leaf:
        c.leaf_flops[cfg.name_at(level)] += float(n) * n * k
        c.leaf_count += 1
        return c
    n1 = cfg.split(n)
    n2 = n - n1
    census_syrk(n1, k, cfg, c, level + 1)
    _gemm(c, cfg.name_at(level), n2, n1, k)
    census_syrk(n2, k, cfg, c, level + 1)
    return c
