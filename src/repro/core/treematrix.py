"""The paper's custom recursive data structure (§III-B) as a JAX pytree.

``TreeSPD`` stores a symmetric matrix the way the paper's Julia solver
does: the diagonal recursion owns high-precision leaf tiles, every
off-diagonal panel is stored *in its level's dtype* together with its
per-block quantization scale. This is the storage (bandwidth) half of
the paper's claim — the dense-array API in core/solve.py reproduces the
*numerics* of low-precision storage via `storage_rounding`, while this
structure realizes the actual memory footprint:

    [F16,F16,F32] at n=65536, leaf 256  =>  0.31x the bytes of dense f32
    [INT8,INT8,F32]                     =>  0.22x

Registered as a pytree, so a TreeSPD can be jit-carried, sharded, and
checkpointed like any other state. ``tree_potrf_packed`` factorizes the
packed form directly, dequantizing panels only at GEMM time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.precision import DTYPES, PrecisionConfig
from repro.core.quantize import quant_block
from repro.core.tree import tree_potrf, tree_trsm, tree_syrk


@jax.tree_util.register_pytree_node_class
class TreeSPD:
    """diag1/diag2: TreeSPD | leaf array (high precision);
    off: (n2, n1) panel stored in its level's dtype; off_scale: f32."""

    def __init__(self, diag1, off, off_scale, diag2, *, level, n1, n):
        self.diag1 = diag1
        self.off = off
        self.off_scale = off_scale
        self.diag2 = diag2
        self.level = level
        self.n1 = n1
        self.n = n

    def tree_flatten(self):
        return ((self.diag1, self.off, self.off_scale, self.diag2),
                (self.level, self.n1, self.n))

    @classmethod
    def tree_unflatten(cls, aux, children):
        level, n1, n = aux
        d1, off, s, d2 = children
        return cls(d1, off, s, d2, level=level, n1=n1, n=n)

    # -- construction -------------------------------------------------------
    @classmethod
    def from_dense(cls, a, cfg: PrecisionConfig, *, level: int = 0):
        n = a.shape[-1]
        assert a.shape == (n, n) and n % cfg.leaf == 0, a.shape
        if n <= cfg.leaf:
            return a.astype(cfg.high_dtype)     # leaf tile, high precision
        n1 = cfg.split(n)
        name = cfg.name_at(level)
        off_q, scale = quant_block(a[n1:, :n1].astype(jnp.float32), name,
                                   cfg.needs_quant(level) or name == "int8")
        return cls(
            cls.from_dense(a[:n1, :n1], cfg, level=level + 1),
            off_q, scale,
            cls.from_dense(a[n1:, n1:], cfg, level=level + 1),
            level=level, n1=n1, n=n)

    # -- back to dense ------------------------------------------------------
    def to_dense(self, dtype=jnp.float32):
        d1 = (self.diag1.to_dense(dtype) if isinstance(self.diag1, TreeSPD)
              else self.diag1.astype(dtype))
        d2 = (self.diag2.to_dense(dtype) if isinstance(self.diag2, TreeSPD)
              else self.diag2.astype(dtype))
        off = self.off.astype(dtype) * self.off_scale.astype(dtype)
        n1, n2 = self.n1, self.n - self.n1
        top = jnp.concatenate([d1, jnp.zeros((n1, n2), dtype)], axis=1)
        bot = jnp.concatenate([off, d2], axis=1)
        return jnp.concatenate([top, bot], axis=0)

    # -- storage accounting (the paper's Fig. 2 memory story) ---------------
    def nbytes(self) -> int:
        b = self.off.dtype.itemsize * self.off.size + 4
        for d in (self.diag1, self.diag2):
            if isinstance(d, TreeSPD):
                b += d.nbytes()
            else:
                b += d.dtype.itemsize * d.size
        return b


def tree_potrf_packed(t, cfg: PrecisionConfig):
    """Factorize a packed TreeSPD; returns a packed lower factor.

    Identical recursion to Alg. 1, but the off-diagonal panel is read
    from (and written back to) its low-precision storage — panels only
    exist densified inside their own TRSM/SYRK calls.
    """
    if not isinstance(t, TreeSPD):
        return tree_potrf(t, cfg, level=0)      # leaf tile

    level = t.level
    name = cfg.name_at(level)
    l11 = tree_potrf_packed(t.diag1, cfg)
    l11_d = l11.to_dense() if isinstance(l11, TreeSPD) else \
        l11.astype(jnp.float32)
    a21 = t.off.astype(jnp.float32) * t.off_scale.astype(jnp.float32)
    l21 = tree_trsm(a21, l11_d, cfg, level=level)
    a22 = (t.diag2.to_dense() if isinstance(t.diag2, TreeSPD)
           else t.diag2.astype(jnp.float32))
    a22 = tree_syrk(a22, l21, alpha=-1.0, beta=1.0, cfg=cfg, level=level)
    l22 = tree_potrf_packed(TreeSPD.from_dense(a22, cfg, level=level + 1)
                            if a22.shape[-1] > cfg.leaf else a22, cfg)
    l21_q, s = quant_block(l21, name,
                           cfg.needs_quant(level) or name == "int8")
    return TreeSPD(l11, l21_q, s, l22, level=level, n1=t.n1, n=t.n)


def storage_ratio(n: int, cfg: PrecisionConfig) -> float:
    """bytes(TreeSPD under cfg) / bytes(dense f32 lower triangle x2) —
    shape-only, no allocation."""
    def rec(n, level):
        if n <= cfg.leaf:
            return n * n * jnp.dtype(cfg.high_dtype).itemsize
        n1 = cfg.split(n)
        n2 = n - n1
        off = n2 * n1 * jnp.dtype(DTYPES[cfg.name_at(level)]).itemsize
        return off + rec(n1, level + 1) + rec(n2, level + 1)

    return rec(n, 0) / (n * n * 4)
