"""Layered precision configuration (the paper's Fig. 2 ladder).

A :class:`PrecisionConfig` carries the per-recursion-level precision list
in the paper's notation: ``levels=("f16", "f16", "f32")`` means recursion
levels 0 and 1 compute their GEMMs in fp16 and every deeper level (and all
leaf POTRF/TRSM/SYRK tiles) runs at f32. The *last* entry is always the
highest precision and is used for diagonal leaves — matching the paper's
``[F16, F16, F32]`` configurations, where precision rises toward the
diagonal.

TPU note (docs/ARCHITECTURE.md, "Precision ladder"): ``bf16`` is the MXU-native low precision and the
recommended default; ``f16`` reproduces the paper's quantization behaviour
bit-for-bit in spirit (narrow exponent, R_max = 65504). ``f64`` levels are
supported on CPU for the accuracy study (enable jax_enable_x64).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

DTYPES = {
    "int8": jnp.int8,        # beyond-paper: v5e MXU int8 = 2x bf16 rate
    "f16": jnp.float16,
    "bf16": jnp.bfloat16,
    "f32": jnp.float32,
    "f64": jnp.float64,
}

# Largest finite value of each format (quantization clamps into +-R_max).
RMAX = {
    "int8": 127.0,
    "f16": 65504.0,
    "bf16": 3.3895314e38,
    "f32": 3.4028235e38,
    "f64": 1.7976931e308,
}

# Formats whose dynamic range is narrow enough that the paper's per-block
# quantization is load-bearing. bf16/f32 share f32's exponent range, so the
# scale is 1 for any physically meaningful input; we skip the absmax pass.
# int8 is *always* scaled (absmax -> [-127, 127]).
NARROW = frozenset({"f16", "int8"})

#: per-chip TPU v5e peak rates used by the throughput model in benchmarks
#: (int8 via the MXU's double-rate integer path), not by the solver.
PEAK_FLOPS = {"int8": 394e12, "f16": 197e12, "bf16": 197e12,
              "f32": 98.5e12, "f64": 0.49e12}


@dataclasses.dataclass(frozen=True)
class PrecisionConfig:
    """Precision ladder + tree geometry for the recursive solver."""

    levels: tuple[str, ...] = ("f32",)
    leaf: int = 256              # leaf tile size b (multiple of 128)
    quantize: bool = True        # per-block quant for NARROW dtypes
    storage_rounding: bool = True  # round updated blocks to their level dtype
    kernel_impl: str | None = None  # ops.py dispatch override
    #: execution engine: "blocked" = flat in-place tile schedule driven by
    #: the static precision plan (core/plan.py + core/blocked.py, the
    #: default); "tree" = the paper's nested recursion (reference oracle);
    #: "auto" = consult the tuning database (repro.tune, docs/TUNING.md)
    #: at factor time for the measured winner at the problem size.
    engine: str = "blocked"

    def __post_init__(self):
        assert self.levels, "need at least one precision level"
        for lv in self.levels:
            assert lv in DTYPES, lv
        assert self.leaf % 128 == 0 and self.leaf > 0, self.leaf
        assert self.engine in ("tree", "blocked", "auto"), self.engine

    # -- ladder ------------------------------------------------------------
    def name_at(self, level: int) -> str:
        return self.levels[min(level, len(self.levels) - 1)]

    def dtype_at(self, level: int):
        return DTYPES[self.name_at(level)]

    @property
    def high_name(self) -> str:
        return self.levels[-1]

    @property
    def high_dtype(self):
        return DTYPES[self.high_name]

    def needs_quant(self, level: int) -> bool:
        name = self.name_at(level)
        if name == "int8":      # int8 is meaningless without its scale
            return True
        return self.quantize and name in NARROW

    # -- geometry ----------------------------------------------------------
    def split(self, n: int) -> int:
        """Leaf-aligned bisection point n1 (paper uses n/2; we round to a
        multiple of the leaf so every tile stays MXU-aligned)."""
        assert n > self.leaf
        return self.leaf * max(1, (n // self.leaf) // 2)

    def depth(self, n: int) -> int:
        """Recursion depth the POTRF tree reaches for size n."""
        d = 0
        while n > self.leaf:
            n -= self.split(n)  # the deeper trailing branch dominates
            d += 1
        return d

    def describe(self) -> str:
        return "[" + ", ".join(s.upper() for s in self.levels) + "]"


# Named configurations matching the paper's figures.
PAPER_CONFIGS = {
    "pure_f64": PrecisionConfig(levels=("f64",)),
    "pure_f32": PrecisionConfig(levels=("f32",)),
    "pure_f16": PrecisionConfig(levels=("f16",)),
    "f16_f32": PrecisionConfig(levels=("f16", "f32")),
    "f16x3_f32": PrecisionConfig(levels=("f16",) * 3 + ("f32",)),
    "f16x5_f32": PrecisionConfig(levels=("f16",) * 5 + ("f32",)),
    "f32x3_f64": PrecisionConfig(levels=("f32",) * 3 + ("f64",)),
    # TPU-native variants (bf16 is the MXU input format)
    "bf16_f32": PrecisionConfig(levels=("bf16", "f32")),
    "bf16x3_f32": PrecisionConfig(levels=("bf16",) * 3 + ("f32",)),
    # beyond-paper: int8 top level rides the v5e MXU double-rate integer
    # path (394 TOPS) — 2.6x model speedup vs uniform f32 at ~3 digits
    "int8_f32": PrecisionConfig(levels=("int8", "f32")),
    "int8x3_f32": PrecisionConfig(levels=("int8",) * 3 + ("f32",)),
}
