"""Mixed-precision iterative refinement over the tree-Cholesky ladders.

The paper's recursive precision ladder trades digits for MXU throughput;
this module claws the digits back the HPL-MxP way: factor ONCE in the
cheap ladder, then iterate

    r_k = b - A x_k          (high "residual" precision)
    d_k = (L L^T)^{-1} r_k   (cheap mixed-precision tree solves)
    x_{k+1} = x_k + d_k      (high precision accumulate)

Classic IR converges linearly at rate ~ cond(A) * eps(ladder); each sweep
costs two O(n^2) tree-TRSMs + one O(n^2) residual GEMM, so a handful of
sweeps turns a ~3-digit f16 factorization into a working-precision solve
at low-precision factorization speed (Abdelfattah et al. 2020, Dongarra &
Luszczek 2025). For ill-conditioned systems where classic IR stalls
(cond(A) * eps(ladder) >~ 1), :func:`gmres_refine` runs restarted GMRES
right-preconditioned by the same cheap factor (GMRES-IR, Carson &
Higham 2017).

Everything here is jit-compatible: iteration bounds are static, early
exit is a ``lax.while_loop``, and results come back as a
:class:`RefineResult` pytree (solution, residual history, sweep count,
converged flag). The operator-level entry points (:func:`refine_operator`,
:func:`refine_steps`) take ``matvec``/``correct`` callables so callers
that already hold a factor — the K-FAC optimizer, the serve engine — can
reuse it across sweeps without re-factorizing.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.precision import DTYPES, PrecisionConfig
from repro.core.solve import cholesky, solve_factored

_TINY = 1e-30


@dataclasses.dataclass(frozen=True)
class RefineConfig:
    """Static refinement policy (hashable: usable as a jit static arg)."""

    max_sweeps: int = 5          # classic-IR sweeps / GMRES restarts
    tol: float = 1e-10           # relative-residual early-exit target
    method: str = "ir"           # "ir" | "gmres"
    gmres_restart: int = 16      # Krylov dimension per GMRES cycle
    residual_dtype: str | None = None  # None -> f64 if x64 is on, else f32

    def __post_init__(self):
        assert self.max_sweeps >= 0, self.max_sweeps
        assert self.method in ("ir", "gmres"), self.method
        assert self.gmres_restart >= 1, self.gmres_restart
        if self.residual_dtype is not None:
            assert self.residual_dtype in DTYPES, self.residual_dtype

    def rdtype(self):
        if self.residual_dtype is not None:
            return DTYPES[self.residual_dtype]
        return jnp.float64 if jax.config.jax_enable_x64 else jnp.float32


class RefineResult(NamedTuple):
    """Pytree result of a refinement run.

    ``history[0]`` is the pre-refinement relative residual; ``history[k]``
    the residual after sweep k (``nan`` for sweeps never run).
    """

    x: jax.Array            # refined solution, residual dtype
    residual: jax.Array     # final relative residual (scalar)
    history: jax.Array      # [max_sweeps + 1] relative residuals
    iterations: jax.Array   # int32 sweeps actually taken
    converged: jax.Array    # bool, residual <= tol


# ---------------------------------------------------------------------------
# operator-level core (factor-agnostic; K-FAC and serve reuse these)
# ---------------------------------------------------------------------------
def scaled_solve(correct: Callable) -> Callable:
    """Wrap a linear corrector with absmax pre-scaling.

    As IR converges the residual shrinks below f16's smallest normal
    (6.1e-5) and the per-block quantizer — which only scales *down*
    (alpha >= 1) — lets it underflow into subnormals, stalling
    convergence. Scaling r to O(1) before the solve and back after is
    exact for a linear operator and is what HPL-MxP does.
    """
    def wrapped(r):
        s = jnp.maximum(jnp.max(jnp.abs(r)), _TINY)
        return correct(r / s) * s

    return wrapped



def _refine_loop(sweep: Callable, relres: Callable, x0,
                 rcfg: RefineConfig) -> RefineResult:
    """Shared outer loop: run ``sweep`` until tol / max_sweeps / stall.

    Tracks the BEST iterate seen, not the last one: when refinement
    stalls or diverges (residual precision floor, preconditioner too
    weak) the caller gets back an x no worse than its starting point,
    and the loop exits instead of burning the remaining sweeps.
    ``history`` still records every attempted sweep.
    """
    rel0 = relres(x0)
    hist0 = jnp.full((rcfg.max_sweeps + 1,), jnp.nan,
                     rel0.dtype).at[0].set(rel0)
    state = (x0, rel0, x0, rel0, hist0, jnp.int32(0),
             jnp.asarray(False))

    def cond(s):
        _, rel, _, _, _, i, stalled = s
        return (i < rcfg.max_sweeps) & (rel > rcfg.tol) & (~stalled)

    def body(s):
        x, rel, bx, brel, hist, i, _ = s
        xn = sweep(x)
        reln = relres(xn)
        hist = hist.at[i + 1].set(reln)
        bx = jnp.where(reln < brel, xn, bx)
        brel = jnp.minimum(reln, brel)
        return xn, reln, bx, brel, hist, i + 1, reln >= rel

    _, _, bx, brel, hist, it, _ = lax.while_loop(cond, body, state)
    return RefineResult(bx, brel, hist, it, brel <= rcfg.tol)


def refine_operator(matvec: Callable, correct: Callable, b, x0,
                    rcfg: RefineConfig) -> RefineResult:
    """Classic IR on an abstract operator.

    ``matvec(x)`` applies A in the residual precision; ``correct(r)``
    applies the cheap approximate inverse (e.g. two tree-TRSMs with a
    cached factor). Early-exits once the relative residual hits
    ``rcfg.tol``, refinement stops improving, or ``rcfg.max_sweeps``
    sweeps have run; returns the best iterate seen.
    """
    rdtype = rcfg.rdtype()
    b = b.astype(rdtype)
    x0 = x0.astype(rdtype)
    bnorm = jnp.maximum(jnp.linalg.norm(b), _TINY)

    def relres(x):
        return (jnp.linalg.norm(b - matvec(x)) / bnorm).astype(rdtype)

    def sweep(x):
        return x + correct(b - matvec(x)).astype(rdtype)

    return _refine_loop(sweep, relres, x0, rcfg)


def refine_steps(matvec: Callable, correct: Callable, b, x, sweeps: int):
    """Fixed-sweep classic IR, fully unrolled — the hot-path variant for
    per-step optimizer use (no norms, no control flow, vmap-friendly)."""
    for _ in range(sweeps):
        x = x + correct(b - matvec(x)).astype(x.dtype)
    return x


def gmres_operator(matvec: Callable, correct: Callable, b, x0,
                   rcfg: RefineConfig) -> RefineResult:
    """Restarted GMRES right-preconditioned by ``correct`` (GMRES-IR).

    Each restart runs an ``rcfg.gmres_restart``-dimensional Arnoldi
    process on ``A M^{-1}`` (modified Gram-Schmidt), solves the small
    least-squares problem, and applies ``x += M^{-1} V y``. The outer
    loop recomputes the TRUE residual in the residual precision and
    shares :func:`_refine_loop` with classic IR, so ``max_sweeps``
    counts restarts and the two methods share a result contract
    (best-iterate, stall detection, history).
    """
    rdtype = rcfg.rdtype()
    m = rcfg.gmres_restart
    b = b.astype(rdtype)
    x0 = x0.astype(rdtype)
    shape = b.shape
    n = b.size  # multi-RHS solves flatten: A (x) I_k is block-diagonal
    bnorm = jnp.maximum(jnp.linalg.norm(b), _TINY)

    def opvec(v):  # v flat, in the preconditioned (u) space
        return matvec(correct(v.reshape(shape)).astype(rdtype)).ravel()

    def cycle(r_flat):
        beta = jnp.linalg.norm(r_flat)
        v0 = r_flat / jnp.maximum(beta, _TINY)
        vs = jnp.zeros((m + 1, n), rdtype).at[0].set(v0)
        hess = jnp.zeros((m + 1, m), rdtype)

        def arnoldi(j, carry):
            vs, hess = carry
            w = opvec(vs[j])

            def mgs(k, wh):
                # rows past j are still zero, so their projections vanish
                w, hcol = wh
                hk = jnp.vdot(vs[k], w)
                return w - hk * vs[k], hcol.at[k].set(hk)

            w, hcol = lax.fori_loop(0, m + 1, mgs,
                                    (w, jnp.zeros(m + 1, rdtype)))
            hj1 = jnp.linalg.norm(w)
            vnext = jnp.where(hj1 > _TINY, w / jnp.maximum(hj1, _TINY), 0.0)
            hess = hess.at[:, j].set(hcol).at[j + 1, j].set(hj1)
            return vs.at[j + 1].set(vnext), hess

        vs, hess = lax.fori_loop(0, m, arnoldi, (vs, hess))
        e1 = jnp.zeros(m + 1, rdtype).at[0].set(beta)
        y, *_ = jnp.linalg.lstsq(hess, e1)
        return (vs[:m].T @ y).reshape(shape)  # u-space correction

    def relres(x):
        return (jnp.linalg.norm(b - matvec(x)) / bnorm).astype(rdtype)

    def sweep(x):
        du = cycle((b - matvec(x)).ravel())
        return x + correct(du).astype(rdtype)

    return _refine_loop(sweep, relres, x0, rcfg)


# ---------------------------------------------------------------------------
# matrix-level drivers
# ---------------------------------------------------------------------------
def _as_refine_config(refine) -> RefineConfig:
    if isinstance(refine, RefineConfig):
        return refine
    if isinstance(refine, int):
        return RefineConfig(max_sweeps=refine)
    if refine is None:
        return RefineConfig()
    raise TypeError(f"refine must be int | RefineConfig | None: {refine!r}")


def iterative_refine(a, b, cfg: PrecisionConfig | None = None,
                     refine: int | RefineConfig | None = None, *,
                     l=None) -> RefineResult:
    """Factor once in ``cfg``'s ladder, refine to ``refine.tol``.

    ``a`` is required here (the residual needs it) in the residual
    precision; pass a precomputed ``l`` to skip the factorization.
    Dispatches on ``refine.method``: classic IR or GMRES-IR.
    """
    cfg = cfg or PrecisionConfig()
    rcfg = _as_refine_config(refine)
    rdtype = rcfg.rdtype()
    assert a is not None, "refinement forms residuals b - A x: pass A"
    if l is None:
        l = cholesky(a, cfg)
    a_r = jnp.asarray(a, rdtype)

    def matvec(x):
        return a_r @ x

    def base_solve(r):
        return solve_factored(l, r.astype(l.dtype), cfg).astype(rdtype)

    correct = scaled_solve(base_solve)
    # the initial solve is unscaled so refine=0 reproduces cholesky_solve
    x0 = base_solve(jnp.asarray(b, rdtype))
    run = gmres_operator if rcfg.method == "gmres" else refine_operator
    return run(matvec, correct, jnp.asarray(b, rdtype), x0, rcfg)


def gmres_refine(a, b, cfg: PrecisionConfig | None = None,
                 refine: int | RefineConfig | None = None, *,
                 l=None) -> RefineResult:
    """GMRES-IR convenience wrapper (``method`` forced to ``"gmres"``)."""
    rcfg = dataclasses.replace(_as_refine_config(refine), method="gmres")
    return iterative_refine(a, b, cfg, rcfg, l=l)
