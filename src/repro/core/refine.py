"""Mixed-precision iterative refinement over the tree-Cholesky ladders.

The paper's recursive precision ladder trades digits for MXU throughput;
this module claws the digits back the HPL-MxP way: factor ONCE in the
cheap ladder, then iterate

    r_k = b - A x_k          (high "residual" precision)
    d_k = (L L^T)^{-1} r_k   (cheap mixed-precision tree solves)
    x_{k+1} = x_k + d_k      (high precision accumulate)

Classic IR converges linearly at rate ~ cond(A) * eps(ladder); each sweep
costs two O(n^2) tree-TRSMs + one O(n^2) residual GEMM, so a handful of
sweeps turns a ~3-digit f16 factorization into a working-precision solve
at low-precision factorization speed (Abdelfattah et al. 2020, Dongarra &
Luszczek 2025). For ill-conditioned systems where classic IR stalls
(cond(A) * eps(ladder) >~ 1), :func:`gmres_refine` runs restarted GMRES
right-preconditioned by the same cheap factor (GMRES-IR, Carson &
Higham 2017).

Everything here is jit-compatible: iteration bounds are static, early
exit is a ``lax.while_loop``, and results come back as a
:class:`RefineResult` pytree (solution, residual history, sweep count,
converged flag). The operator-level entry points (:func:`refine_operator`,
:func:`refine_steps`) take ``matvec``/``correct`` callables so callers
that already hold a factor — the K-FAC optimizer, the serve engine — can
reuse it across sweeps without re-factorizing.

Multi-RHS refinement is PER-COLUMN: a (n, k) right-hand side gets a
per-column convergence mask, per-column residual history, per-column
sweep counts and (optionally, via ``tol``) per-column tolerances, so one
slow column doesn't burn sweeps for converged neighbors — the serve
scheduler stacks cross-request RHS into one such call. Columns that
converge (or stall) are frozen at their best iterate while the rest keep
sweeping; each sweep forms ONE residual (carried between iterations, and
fused into a single Pallas kernel on TPU — see
:mod:`repro.kernels.residual`) instead of the naive two.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.precision import DTYPES, PrecisionConfig
from repro.core.solve import cholesky_padded, solve_factored
from repro.kernels import ops

_TINY = 1e-30


@dataclasses.dataclass(frozen=True)
class RefineConfig:
    """Static refinement policy (hashable: usable as a jit static arg)."""

    max_sweeps: int = 5          # classic-IR sweeps / GMRES restarts
    tol: float = 1e-10           # relative-residual early-exit target
    method: str = "ir"           # "ir" | "gmres"
    gmres_restart: int = 16      # Krylov dimension per GMRES cycle
    residual_dtype: str | None = None  # None -> f64 if x64 is on, else f32

    def __post_init__(self):
        assert self.max_sweeps >= 0, self.max_sweeps
        assert self.method in ("ir", "gmres"), self.method
        assert self.gmres_restart >= 1, self.gmres_restart
        if self.residual_dtype is not None:
            assert self.residual_dtype in DTYPES, self.residual_dtype

    def rdtype(self):
        if self.residual_dtype is not None:
            return DTYPES[self.residual_dtype]
        return jnp.float64 if jax.config.jax_enable_x64 else jnp.float32


class RefineResult(NamedTuple):
    """Pytree result of a refinement run.

    ``history[0]`` is the pre-refinement relative residual; ``history[k]``
    the residual after sweep k (``nan`` for sweeps never run — including,
    for multi-RHS, sweeps where that column was already frozen).

    For a vector ``b`` the per-column fields are scalars (the PR-1
    contract); for an (n, k) ``b`` they are (k,)-shaped: residual,
    iterations and converged are PER COLUMN and history is
    [max_sweeps + 1, k].
    """

    x: jax.Array            # refined solution, residual dtype
    residual: jax.Array     # final relative residual, scalar | (k,)
    history: jax.Array      # [max_sweeps + 1(, k)] relative residuals
    iterations: jax.Array   # int32 sweeps actually taken, scalar | (k,)
    converged: jax.Array    # bool residual <= tol, scalar | (k,)


# ---------------------------------------------------------------------------
# operator-level core (factor-agnostic; K-FAC and serve reuse these)
# ---------------------------------------------------------------------------
def scaled_solve(correct: Callable) -> Callable:
    """Wrap a linear corrector with PER-COLUMN absmax pre-scaling.

    As IR converges the residual shrinks below f16's smallest normal
    (6.1e-5) and the per-block quantizer — which only scales *down*
    (alpha >= 1) — lets it underflow into subnormals, stalling
    convergence. Scaling r to O(1) before the solve and back after is
    exact for a linear operator and is what HPL-MxP does.

    The scale is per COLUMN for multi-RHS blocks: the serve scheduler
    stacks unrelated requests whose residual magnitudes can differ by
    orders of magnitude (different RHS norms, different convergence
    stages), and a single joint absmax would underflow every small
    column next to a large neighbor. Column-wise scaling is still exact
    — the corrector solves columns independently.
    """
    def wrapped(r):
        absmax = (jnp.max(jnp.abs(r), axis=0, keepdims=True)
                  if r.ndim == 2 else jnp.max(jnp.abs(r)))
        s = jnp.maximum(absmax, _TINY)
        return correct(r / s) * s

    return wrapped



def _colnorm(v):
    """Per-column 2-norm: scalar for a vector, (k,) for an (n, k) block."""
    return jnp.linalg.norm(v, axis=0) if v.ndim == 2 else jnp.linalg.norm(v)


def _masked_sweep(sweep: Callable, resid: Callable, relnorm: Callable,
                  x, r, rel, bx, brel, its, stall, act):
    """One per-column-masked refinement sweep — the shared inner step.

    Both refinement drivers run exactly this math per sweep: the jitted
    window loop (:func:`_refine_loop`) inside a ``lax.while_loop``, and
    the re-entrant slot stepper (:class:`RefineStepper`) once per host
    visit, so a column's trajectory is identical whichever loop drives
    it (the continuous==window determinism contract, pinned by
    tests/test_serve_continuous.py).  ``act`` masks the sweep: frozen
    columns keep their iterate, their residual columns are zeroed out of
    the sweep input, and their bookkeeping (best iterate, stall counter,
    sweep count) does not advance.
    """
    rm = r * act.astype(r.dtype)             # mask frozen residuals
    xn = jnp.where(act, sweep(x, rm), x)     # frozen columns keep x
    rn = resid(xn)
    reln = jnp.where(act, relnorm(rn), rel)
    improved = reln < brel                   # new best this sweep?
    bx = jnp.where(act & improved, xn, bx)
    brel = jnp.where(act, jnp.minimum(reln, brel), brel)
    stall = jnp.where(act, jnp.where(improved, 0, stall + 1), stall)
    return xn, rn, reln, bx, brel, its + act.astype(jnp.int32), stall


def _refine_loop(sweep: Callable, resid: Callable, relnorm: Callable, x0,
                 rcfg: RefineConfig, tol=None) -> RefineResult:
    """Shared outer loop: run ``sweep`` until tol / max_sweeps / stall,
    with PER-COLUMN bookkeeping for multi-RHS blocks.

    ``resid(x)`` forms the residual (one GEMM — it is carried between
    iterations so each sweep costs a single residual evaluation, and is
    the seam the fused Pallas kernel plugs into); ``relnorm(r)`` maps it
    to per-column relative norms; ``sweep(x, r)`` applies one correction.

    Tracks the BEST iterate seen per column, not the last one: when a
    column stalls or diverges (residual precision floor, preconditioner
    too weak) the caller gets back an x no worse than its starting
    point. A column exits on convergence or after TWO consecutive
    non-improving sweeps (no new per-column best) — a single flat sweep
    is a normal transient for GMRES-IR restarts and non-normal IR
    iterations, so it must not abort the run. Converged/stalled columns
    are frozen while the rest keep sweeping, so one slow RHS doesn't
    burn sweeps for its neighbors; their residual columns are zeroed
    out of the sweep input so a frozen (possibly diverged) column can't
    hijack a joint GMRES-IR restart. ``tol`` may be a per-column array
    (the serve scheduler passes per-request accuracy targets); it
    defaults to the scalar ``rcfg.tol``.
    """
    r0 = resid(x0)
    rel0 = relnorm(r0)
    tol = jnp.asarray(rcfg.tol if tol is None else tol, rel0.dtype)
    hist0 = jnp.full((rcfg.max_sweeps + 1,) + rel0.shape, jnp.nan,
                     rel0.dtype).at[0].set(rel0)
    zero = jnp.zeros(rel0.shape, jnp.int32)
    state = (x0, r0, rel0, x0, rel0, hist0, zero, zero, jnp.int32(0))

    def active(brel, stall):
        return (brel > tol) & (stall < 2)

    def cond(s):
        _, _, _, _, brel, _, _, stall, i = s
        return (i < rcfg.max_sweeps) & jnp.any(active(brel, stall))

    def body(s):
        x, r, rel, bx, brel, hist, its, stall, i = s
        act = active(brel, stall)
        xn, rn, reln, bx, brel, its, stall = _masked_sweep(
            sweep, resid, relnorm, x, r, rel, bx, brel, its, stall, act)
        hist = hist.at[i + 1].set(jnp.where(act, reln, jnp.nan))
        return (xn, rn, reln, bx, brel, hist, its, stall, i + 1)

    _, _, _, bx, brel, hist, its, _, _ = lax.while_loop(cond, body, state)
    return RefineResult(bx, brel, hist, its, brel <= tol)


# ---------------------------------------------------------------------------
# re-entrant slot-block refinement (continuous batching)
# ---------------------------------------------------------------------------
class SlotState(NamedTuple):
    """Pytree state of a :class:`RefineStepper` slot block.

    One RHS column per slot; ``(n, S)`` arrays hold the block, ``(S,)``
    arrays the per-slot bookkeeping.  Empty slots are all-zero with
    ``occ=False``, ``bnorm=1`` — algebraically inert (their residual is
    0, their correction is 0) so they cost nothing but their share of
    the block GEMM.
    """

    x: jax.Array       # (n, S) current iterate (residual dtype)
    r: jax.Array       # (n, S) carried residual b - A x
    b: jax.Array       # (n, S) right-hand sides
    bx: jax.Array      # (n, S) best iterate seen per slot
    rel: jax.Array     # (S,) latest relative residual
    brel: jax.Array    # (S,) best relative residual
    bnorm: jax.Array   # (S,) ||b|| denominators (1 for empty slots)
    tol: jax.Array     # (S,) per-slot tolerance
    occ: jax.Array     # (S,) bool: slot holds a live column
    its: jax.Array     # (S,) int32 sweeps taken
    stall: jax.Array   # (S,) int32 consecutive non-improving sweeps


class RefineStepper:
    """Re-entrant, slot-addressed refinement loop — the continuous-
    batching core (vLLM's idiom applied to IR sweeps).

    :func:`_refine_loop` runs a whole refinement *window* inside one
    ``lax.while_loop``: every column joins at sweep 0 and the batch
    returns when the last column exits.  The stepper runs the SAME
    per-column-masked sweep (:func:`_masked_sweep`, jitted once per
    ``(n, slots)`` shape) but yields to the host between sweeps, so a
    serving loop can **retire** converged/stalled columns mid-flight
    (freeing their slots) and **join** newly arrived RHS columns into
    the running block without waiting for a window boundary.

    Classic IR is column-local — the correction, residual and scaling
    all act per column — so a column's trajectory is bitwise identical
    whether it runs here or in a window, and independent of which
    co-tenants share its block.  GMRES-IR's joint Krylov space is NOT
    column-local; continuous serving therefore only accepts
    ``method="ir"`` (the scheduler windows GMRES requests).

    ``correct(r)`` applies the cheap factor (already per-column scaled,
    e.g. :func:`scaled_solve`); ``resid(x, b)`` forms ``b - A x`` in the
    residual precision for the whole block (the fused-kernel seam).
    Host-side helpers (:meth:`active_mask`, :meth:`done_mask`,
    :meth:`retire`, :meth:`join`) move only ``(S,)``-sized vectors over
    the device boundary; the block itself stays resident.
    """

    def __init__(self, correct: Callable, resid: Callable, *, n: int,
                 slots: int, rcfg: RefineConfig):
        assert slots >= 1, slots
        self.n, self.slots, self.rcfg = n, slots, rcfg
        self.rdtype = rcfg.rdtype()
        self._correct, self._resid = correct, resid
        self._step = jax.jit(self._step_impl)

    # -- state constructors -------------------------------------------------
    def init(self) -> SlotState:
        n, s, dt = self.n, self.slots, self.rdtype
        z, zs = jnp.zeros((n, s), dt), jnp.zeros((s,), dt)
        return SlotState(x=z, r=z, b=z, bx=z, rel=zs, brel=zs,
                         bnorm=jnp.ones((s,), dt), tol=zs,
                         occ=jnp.zeros((s,), bool),
                         its=jnp.zeros((s,), jnp.int32),
                         stall=jnp.zeros((s,), jnp.int32))

    def join(self, state: SlotState, idx, b_cols, x0_cols,
             tols) -> SlotState:
        """Insert columns into free slots mid-flight.

        ``idx`` are free slot indices (``len(idx)`` columns), ``b_cols``
        / ``x0_cols`` the ``(n, k)`` right-hand sides and initial
        iterates (the caller's base solve — unscaled, exactly like the
        window path's ``x0``), ``tols`` the per-column tolerances.  The
        block residual is recomputed once; live columns' residuals are
        reproduced bitwise (``r`` always equals ``resid(x, b)``), so a
        join never perturbs an in-flight column.
        """
        idx = jnp.asarray(idx, jnp.int32)
        b_cols = jnp.asarray(b_cols, self.rdtype)
        x0_cols = jnp.asarray(x0_cols, self.rdtype)
        new = jnp.zeros((self.slots,), bool).at[idx].set(True)
        x = state.x.at[:, idx].set(x0_cols)
        b = state.b.at[:, idx].set(b_cols)
        bnorm = state.bnorm.at[idx].set(
            jnp.maximum(_colnorm(b_cols), _TINY).astype(self.rdtype))
        r = self._resid(x, b)
        rel = jnp.where(new, (_colnorm(r) / bnorm).astype(self.rdtype),
                        state.rel)
        return SlotState(
            x=x, r=r, b=b, bx=state.bx.at[:, idx].set(x0_cols),
            rel=rel, brel=jnp.where(new, rel, state.brel), bnorm=bnorm,
            tol=state.tol.at[idx].set(jnp.asarray(tols, self.rdtype)),
            occ=state.occ | new,
            its=state.its.at[idx].set(0), stall=state.stall.at[idx].set(0))

    # -- the sweep ----------------------------------------------------------
    def _active(self, state: SlotState):
        return (state.occ & (state.brel > state.tol) & (state.stall < 2)
                & (state.its < self.rcfg.max_sweeps))

    def _step_impl(self, state: SlotState):
        act = self._active(state)

        def resid(x):
            return self._resid(x, state.b)

        def relnorm(r):
            return (_colnorm(r) / state.bnorm).astype(self.rdtype)

        def sweep(x, rm):
            return x + self._correct(rm).astype(self.rdtype)

        xn, rn, reln, bx, brel, its, stall = _masked_sweep(
            sweep, resid, relnorm, state.x, state.r, state.rel, state.bx,
            state.brel, state.its, state.stall, act)
        return SlotState(x=xn, r=rn, b=state.b, bx=bx, rel=reln,
                         brel=brel, bnorm=state.bnorm, tol=state.tol,
                         occ=state.occ, its=its, stall=stall), act

    def step(self, state: SlotState):
        """One masked sweep over the block; returns ``(state, act)``
        where ``act`` is the numpy mask of slots the sweep advanced."""
        state, act = self._step(state)
        return state, np.asarray(act)

    # -- host-side bookkeeping ----------------------------------------------
    def active_mask(self, state: SlotState):
        """Numpy mask of slots that would advance on the next sweep."""
        return np.asarray(self._active(state))

    def done_mask(self, state: SlotState):
        """Numpy mask of occupied slots that are finished (converged,
        stalled twice, or out of sweeps) and ready to retire."""
        return np.asarray(state.occ) & ~self.active_mask(state)

    def retire(self, state: SlotState, idx):
        """Free slots ``idx``; returns ``(state, results)``.

        ``results[i]`` is ``(x, relres, sweeps, converged)`` for slot
        ``idx[i]`` — the BEST iterate seen (the window loop's contract),
        its relative residual, sweep count and convergence flag.  The
        freed slots are zeroed so they stay algebraically inert; a
        retired column is never touched again (its result is copied out
        here, before the slot is recycled).
        """
        ja = jnp.asarray(idx, jnp.int32)
        xs = state.bx[:, ja]                     # one device gather
        brel = np.asarray(state.brel[ja])
        its = np.asarray(state.its[ja])
        conv = brel <= np.asarray(state.tol[ja])
        results = [(xs[:, i], float(brel[i]), int(its[i]), bool(conv[i]))
                   for i in range(len(idx))]
        zc = jnp.zeros((self.n, len(idx)), self.rdtype)
        zv = jnp.zeros((len(idx),), self.rdtype)
        zi = jnp.zeros((len(idx),), jnp.int32)
        state = SlotState(
            x=state.x.at[:, ja].set(zc), r=state.r.at[:, ja].set(zc),
            b=state.b.at[:, ja].set(zc), bx=state.bx.at[:, ja].set(zc),
            rel=state.rel.at[ja].set(zv), brel=state.brel.at[ja].set(zv),
            bnorm=state.bnorm.at[ja].set(jnp.ones_like(zv)),
            tol=state.tol.at[ja].set(zv),
            occ=state.occ.at[ja].set(False),
            its=state.its.at[ja].set(zi), stall=state.stall.at[ja].set(zi))
        return state, results


def refine_operator(matvec: Callable, correct: Callable, b, x0,
                    rcfg: RefineConfig, *, resid: Callable | None = None,
                    tol=None) -> RefineResult:
    """Classic IR on an abstract operator.

    ``matvec(x)`` applies A in the residual precision; ``correct(r)``
    applies the cheap approximate inverse (e.g. two tree-TRSMs with a
    cached factor). ``resid`` overrides the residual evaluation
    ``b - matvec(x)`` — :func:`iterative_refine` passes the fused Pallas
    kernel here. ``tol`` may be per-column (see :func:`_refine_loop`).
    Early-exits once the relative residual hits tolerance, refinement
    stops improving for two consecutive sweeps, or ``rcfg.max_sweeps``
    sweeps have run; returns the best iterate seen (per column).
    """
    rdtype = rcfg.rdtype()
    b = b.astype(rdtype)
    x0 = x0.astype(rdtype)
    if resid is None:
        def resid(x):
            return b - matvec(x)
    bnorm = jnp.maximum(_colnorm(b), _TINY)

    def relnorm(r):
        return (_colnorm(r) / bnorm).astype(rdtype)

    def sweep(x, r):
        return x + correct(r).astype(rdtype)

    return _refine_loop(sweep, resid, relnorm, x0, rcfg, tol)


def refine_steps(matvec: Callable, correct: Callable, b, x, sweeps: int):
    """Fixed-sweep classic IR, fully unrolled — the hot-path variant for
    per-step optimizer use (no norms, no control flow, vmap-friendly)."""
    for _ in range(sweeps):
        x = x + correct(b - matvec(x)).astype(x.dtype)
    return x


def gmres_operator(matvec: Callable, correct: Callable, b, x0,
                   rcfg: RefineConfig, *, resid: Callable | None = None,
                   tol=None) -> RefineResult:
    """Restarted GMRES right-preconditioned by ``correct`` (GMRES-IR).

    Each restart runs an ``rcfg.gmres_restart``-dimensional Arnoldi
    process on ``A M^{-1}`` (modified Gram-Schmidt), solves the small
    least-squares problem, and applies ``x += M^{-1} V y``. The outer
    loop recomputes the TRUE residual in the residual precision and
    shares :func:`_refine_loop` with classic IR, so ``max_sweeps``
    counts restarts and the two methods share a result contract
    (best-iterate per column, two-sweep stall detection, per-column
    history). The Krylov cycle itself stays joint across RHS columns
    (the flattened A (x) I_k operator); only the outer convergence
    bookkeeping is per column.
    """
    rdtype = rcfg.rdtype()
    m = rcfg.gmres_restart
    b = b.astype(rdtype)
    x0 = x0.astype(rdtype)
    if resid is None:
        def resid(x):
            return b - matvec(x)
    shape = b.shape
    n = b.size  # multi-RHS solves flatten: A (x) I_k is block-diagonal
    bnorm = jnp.maximum(_colnorm(b), _TINY)

    def opvec(v):  # v flat, in the preconditioned (u) space
        return matvec(correct(v.reshape(shape)).astype(rdtype)).ravel()

    def cycle(r_flat):
        beta = jnp.linalg.norm(r_flat)
        v0 = r_flat / jnp.maximum(beta, _TINY)
        vs = jnp.zeros((m + 1, n), rdtype).at[0].set(v0)
        hess = jnp.zeros((m + 1, m), rdtype)

        def arnoldi(j, carry):
            vs, hess = carry
            w = opvec(vs[j])

            def mgs(k, wh):
                # rows past j are still zero, so their projections vanish
                w, hcol = wh
                hk = jnp.vdot(vs[k], w)
                return w - hk * vs[k], hcol.at[k].set(hk)

            w, hcol = lax.fori_loop(0, m + 1, mgs,
                                    (w, jnp.zeros(m + 1, rdtype)))
            hj1 = jnp.linalg.norm(w)
            vnext = jnp.where(hj1 > _TINY, w / jnp.maximum(hj1, _TINY), 0.0)
            hess = hess.at[:, j].set(hcol).at[j + 1, j].set(hj1)
            return vs.at[j + 1].set(vnext), hess

        vs, hess = lax.fori_loop(0, m, arnoldi, (vs, hess))
        e1 = jnp.zeros(m + 1, rdtype).at[0].set(beta)
        y, *_ = jnp.linalg.lstsq(hess, e1)
        return (vs[:m].T @ y).reshape(shape)  # u-space correction

    def relnorm(r):
        return (_colnorm(r) / bnorm).astype(rdtype)

    def sweep(x, r):
        du = cycle(r.ravel())
        return x + correct(du).astype(rdtype)

    return _refine_loop(sweep, resid, relnorm, x0, rcfg, tol)


# ---------------------------------------------------------------------------
# matrix-level drivers
# ---------------------------------------------------------------------------
def _as_refine_config(refine) -> RefineConfig:
    if isinstance(refine, RefineConfig):
        return refine
    if isinstance(refine, int):
        return RefineConfig(max_sweeps=refine)
    if refine is None:
        return RefineConfig()
    raise TypeError(f"refine must be int | RefineConfig | None: {refine!r}")


def iterative_refine(a, b, cfg: PrecisionConfig | None = None,
                     refine: int | RefineConfig | None = None, *,
                     l=None, col_tol=None, linvs=None) -> RefineResult:
    """Factor once in ``cfg``'s ladder, refine to ``refine.tol``.

    ``a`` is required here (the residual needs it) in the residual
    precision; pass a precomputed ``l`` to skip the factorization.
    Dispatches on ``refine.method``: classic IR or GMRES-IR. The sweep
    residual ``b - A x`` goes through :func:`repro.kernels.ops.residual`
    — the fused Pallas kernel on TPU (or when ``cfg.kernel_impl``
    forces it), the XLA oracle elsewhere. ``col_tol`` gives an (n, k)
    ``b`` per-column tolerances overriding the scalar ``refine.tol``
    (the serve scheduler's per-request accuracy targets). ``linvs``
    reuses cached diagonal-tile inverses across every sweep's pair of
    triangular solves (blocked engine; see ``core.blocked.diag_tri_inv``).
    """
    cfg = cfg or PrecisionConfig()
    rcfg = _as_refine_config(refine)
    rdtype = rcfg.rdtype()
    assert a is not None, "refinement forms residuals b - A x: pass A"
    if l is None:
        l = cholesky_padded(a, cfg)   # solves consume the padded form
    if linvs is None and cfg.engine == "blocked":
        # every sweep runs two triangular passes against the same factor:
        # invert the diagonal leaves once here instead of per sweep
        from repro.core.blocked import diag_tri_inv
        from repro.core.tree import pad_factor
        l = pad_factor(l, cfg.leaf)
        linvs = diag_tri_inv(l, cfg)
    a_r = jnp.asarray(a, rdtype)
    b_r = jnp.asarray(b, rdtype)

    def matvec(x):
        return a_r @ x

    def resid(x):
        return ops.residual(a_r, x, b_r, impl=cfg.kernel_impl)

    def base_solve(r):
        return solve_factored(l, r.astype(l.dtype), cfg,
                              linvs=linvs).astype(rdtype)

    correct = scaled_solve(base_solve)
    # the initial solve is unscaled so refine=0 reproduces cholesky_solve
    x0 = base_solve(b_r)
    run = gmres_operator if rcfg.method == "gmres" else refine_operator
    return run(matvec, correct, b_r, x0, rcfg, resid=resid, tol=col_tol)


def gmres_refine(a, b, cfg: PrecisionConfig | None = None,
                 refine: int | RefineConfig | None = None, *,
                 l=None, col_tol=None, linvs=None) -> RefineResult:
    """GMRES-IR convenience wrapper (``method`` forced to ``"gmres"``)."""
    rcfg = dataclasses.replace(_as_refine_config(refine), method="gmres")
    return iterative_refine(a, b, cfg, rcfg, l=l, col_tol=col_tol,
                            linvs=linvs)
