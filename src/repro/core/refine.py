"""Mixed-precision iterative refinement over the tree-Cholesky ladders.

The paper's recursive precision ladder trades digits for MXU throughput;
this module claws the digits back the HPL-MxP way: factor ONCE in the
cheap ladder, then iterate

    r_k = b - A x_k          (high "residual" precision)
    d_k = (L L^T)^{-1} r_k   (cheap mixed-precision tree solves)
    x_{k+1} = x_k + d_k      (high precision accumulate)

Classic IR converges linearly at rate ~ cond(A) * eps(ladder); each sweep
costs two O(n^2) tree-TRSMs + one O(n^2) residual GEMM, so a handful of
sweeps turns a ~3-digit f16 factorization into a working-precision solve
at low-precision factorization speed (Abdelfattah et al. 2020, Dongarra &
Luszczek 2025). For ill-conditioned systems where classic IR stalls
(cond(A) * eps(ladder) >~ 1), :func:`gmres_refine` runs restarted GMRES
right-preconditioned by the same cheap factor (GMRES-IR, Carson &
Higham 2017).

Everything here is jit-compatible: iteration bounds are static, early
exit is a ``lax.while_loop``, and results come back as a
:class:`RefineResult` pytree (solution, residual history, sweep count,
converged flag). The operator-level entry points (:func:`refine_operator`,
:func:`refine_steps`) take ``matvec``/``correct`` callables so callers
that already hold a factor — the K-FAC optimizer, the serve engine — can
reuse it across sweeps without re-factorizing.

Multi-RHS refinement is PER-COLUMN: a (n, k) right-hand side gets a
per-column convergence mask, per-column residual history, per-column
sweep counts and (optionally, via ``tol``) per-column tolerances, so one
slow column doesn't burn sweeps for converged neighbors — the serve
scheduler stacks cross-request RHS into one such call. Columns that
converge (or stall) are frozen at their best iterate while the rest keep
sweeping; each sweep forms ONE residual (carried between iterations, and
fused into a single Pallas kernel on TPU — see
:mod:`repro.kernels.residual`) instead of the naive two.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.precision import DTYPES, PrecisionConfig
from repro.core.solve import cholesky_padded, solve_factored
from repro.kernels import ops

_TINY = 1e-30


@dataclasses.dataclass(frozen=True)
class RefineConfig:
    """Static refinement policy (hashable: usable as a jit static arg)."""

    max_sweeps: int = 5          # classic-IR sweeps / GMRES restarts
    tol: float = 1e-10           # relative-residual early-exit target
    method: str = "ir"           # "ir" | "gmres"
    gmres_restart: int = 16      # Krylov dimension per GMRES cycle
    residual_dtype: str | None = None  # None -> f64 if x64 is on, else f32

    def __post_init__(self):
        assert self.max_sweeps >= 0, self.max_sweeps
        assert self.method in ("ir", "gmres"), self.method
        assert self.gmres_restart >= 1, self.gmres_restart
        if self.residual_dtype is not None:
            assert self.residual_dtype in DTYPES, self.residual_dtype

    def rdtype(self):
        if self.residual_dtype is not None:
            return DTYPES[self.residual_dtype]
        return jnp.float64 if jax.config.jax_enable_x64 else jnp.float32


class RefineResult(NamedTuple):
    """Pytree result of a refinement run.

    ``history[0]`` is the pre-refinement relative residual; ``history[k]``
    the residual after sweep k (``nan`` for sweeps never run — including,
    for multi-RHS, sweeps where that column was already frozen).

    For a vector ``b`` the per-column fields are scalars (the PR-1
    contract); for an (n, k) ``b`` they are (k,)-shaped: residual,
    iterations and converged are PER COLUMN and history is
    [max_sweeps + 1, k].
    """

    x: jax.Array            # refined solution, residual dtype
    residual: jax.Array     # final relative residual, scalar | (k,)
    history: jax.Array      # [max_sweeps + 1(, k)] relative residuals
    iterations: jax.Array   # int32 sweeps actually taken, scalar | (k,)
    converged: jax.Array    # bool residual <= tol, scalar | (k,)


# ---------------------------------------------------------------------------
# operator-level core (factor-agnostic; K-FAC and serve reuse these)
# ---------------------------------------------------------------------------
def scaled_solve(correct: Callable) -> Callable:
    """Wrap a linear corrector with PER-COLUMN absmax pre-scaling.

    As IR converges the residual shrinks below f16's smallest normal
    (6.1e-5) and the per-block quantizer — which only scales *down*
    (alpha >= 1) — lets it underflow into subnormals, stalling
    convergence. Scaling r to O(1) before the solve and back after is
    exact for a linear operator and is what HPL-MxP does.

    The scale is per COLUMN for multi-RHS blocks: the serve scheduler
    stacks unrelated requests whose residual magnitudes can differ by
    orders of magnitude (different RHS norms, different convergence
    stages), and a single joint absmax would underflow every small
    column next to a large neighbor. Column-wise scaling is still exact
    — the corrector solves columns independently.
    """
    def wrapped(r):
        absmax = (jnp.max(jnp.abs(r), axis=0, keepdims=True)
                  if r.ndim == 2 else jnp.max(jnp.abs(r)))
        s = jnp.maximum(absmax, _TINY)
        return correct(r / s) * s

    return wrapped



def _colnorm(v):
    """Per-column 2-norm: scalar for a vector, (k,) for an (n, k) block."""
    return jnp.linalg.norm(v, axis=0) if v.ndim == 2 else jnp.linalg.norm(v)


def _refine_loop(sweep: Callable, resid: Callable, relnorm: Callable, x0,
                 rcfg: RefineConfig, tol=None) -> RefineResult:
    """Shared outer loop: run ``sweep`` until tol / max_sweeps / stall,
    with PER-COLUMN bookkeeping for multi-RHS blocks.

    ``resid(x)`` forms the residual (one GEMM — it is carried between
    iterations so each sweep costs a single residual evaluation, and is
    the seam the fused Pallas kernel plugs into); ``relnorm(r)`` maps it
    to per-column relative norms; ``sweep(x, r)`` applies one correction.

    Tracks the BEST iterate seen per column, not the last one: when a
    column stalls or diverges (residual precision floor, preconditioner
    too weak) the caller gets back an x no worse than its starting
    point. A column exits on convergence or after TWO consecutive
    non-improving sweeps (no new per-column best) — a single flat sweep
    is a normal transient for GMRES-IR restarts and non-normal IR
    iterations, so it must not abort the run. Converged/stalled columns
    are frozen while the rest keep sweeping, so one slow RHS doesn't
    burn sweeps for its neighbors; their residual columns are zeroed
    out of the sweep input so a frozen (possibly diverged) column can't
    hijack a joint GMRES-IR restart. ``tol`` may be a per-column array
    (the serve scheduler passes per-request accuracy targets); it
    defaults to the scalar ``rcfg.tol``.
    """
    r0 = resid(x0)
    rel0 = relnorm(r0)
    tol = jnp.asarray(rcfg.tol if tol is None else tol, rel0.dtype)
    hist0 = jnp.full((rcfg.max_sweeps + 1,) + rel0.shape, jnp.nan,
                     rel0.dtype).at[0].set(rel0)
    zero = jnp.zeros(rel0.shape, jnp.int32)
    state = (x0, r0, rel0, x0, rel0, hist0, zero, zero, jnp.int32(0))

    def active(brel, stall):
        return (brel > tol) & (stall < 2)

    def cond(s):
        _, _, _, _, brel, _, _, stall, i = s
        return (i < rcfg.max_sweeps) & jnp.any(active(brel, stall))

    def body(s):
        x, r, rel, bx, brel, hist, its, stall, i = s
        act = active(brel, stall)
        rm = r * act.astype(r.dtype)             # mask frozen residuals
        xn = jnp.where(act, sweep(x, rm), x)     # frozen columns keep x
        rn = resid(xn)
        reln = jnp.where(act, relnorm(rn), rel)
        hist = hist.at[i + 1].set(jnp.where(act, reln, jnp.nan))
        improved = reln < brel                   # new best this sweep?
        bx = jnp.where(act & improved, xn, bx)
        brel = jnp.where(act, jnp.minimum(reln, brel), brel)
        stall = jnp.where(act, jnp.where(improved, 0, stall + 1), stall)
        return (xn, rn, reln, bx, brel, hist, its + act.astype(jnp.int32),
                stall, i + 1)

    _, _, _, bx, brel, hist, its, _, _ = lax.while_loop(cond, body, state)
    return RefineResult(bx, brel, hist, its, brel <= tol)


def refine_operator(matvec: Callable, correct: Callable, b, x0,
                    rcfg: RefineConfig, *, resid: Callable | None = None,
                    tol=None) -> RefineResult:
    """Classic IR on an abstract operator.

    ``matvec(x)`` applies A in the residual precision; ``correct(r)``
    applies the cheap approximate inverse (e.g. two tree-TRSMs with a
    cached factor). ``resid`` overrides the residual evaluation
    ``b - matvec(x)`` — :func:`iterative_refine` passes the fused Pallas
    kernel here. ``tol`` may be per-column (see :func:`_refine_loop`).
    Early-exits once the relative residual hits tolerance, refinement
    stops improving for two consecutive sweeps, or ``rcfg.max_sweeps``
    sweeps have run; returns the best iterate seen (per column).
    """
    rdtype = rcfg.rdtype()
    b = b.astype(rdtype)
    x0 = x0.astype(rdtype)
    if resid is None:
        def resid(x):
            return b - matvec(x)
    bnorm = jnp.maximum(_colnorm(b), _TINY)

    def relnorm(r):
        return (_colnorm(r) / bnorm).astype(rdtype)

    def sweep(x, r):
        return x + correct(r).astype(rdtype)

    return _refine_loop(sweep, resid, relnorm, x0, rcfg, tol)


def refine_steps(matvec: Callable, correct: Callable, b, x, sweeps: int):
    """Fixed-sweep classic IR, fully unrolled — the hot-path variant for
    per-step optimizer use (no norms, no control flow, vmap-friendly)."""
    for _ in range(sweeps):
        x = x + correct(b - matvec(x)).astype(x.dtype)
    return x


def gmres_operator(matvec: Callable, correct: Callable, b, x0,
                   rcfg: RefineConfig, *, resid: Callable | None = None,
                   tol=None) -> RefineResult:
    """Restarted GMRES right-preconditioned by ``correct`` (GMRES-IR).

    Each restart runs an ``rcfg.gmres_restart``-dimensional Arnoldi
    process on ``A M^{-1}`` (modified Gram-Schmidt), solves the small
    least-squares problem, and applies ``x += M^{-1} V y``. The outer
    loop recomputes the TRUE residual in the residual precision and
    shares :func:`_refine_loop` with classic IR, so ``max_sweeps``
    counts restarts and the two methods share a result contract
    (best-iterate per column, two-sweep stall detection, per-column
    history). The Krylov cycle itself stays joint across RHS columns
    (the flattened A (x) I_k operator); only the outer convergence
    bookkeeping is per column.
    """
    rdtype = rcfg.rdtype()
    m = rcfg.gmres_restart
    b = b.astype(rdtype)
    x0 = x0.astype(rdtype)
    if resid is None:
        def resid(x):
            return b - matvec(x)
    shape = b.shape
    n = b.size  # multi-RHS solves flatten: A (x) I_k is block-diagonal
    bnorm = jnp.maximum(_colnorm(b), _TINY)

    def opvec(v):  # v flat, in the preconditioned (u) space
        return matvec(correct(v.reshape(shape)).astype(rdtype)).ravel()

    def cycle(r_flat):
        beta = jnp.linalg.norm(r_flat)
        v0 = r_flat / jnp.maximum(beta, _TINY)
        vs = jnp.zeros((m + 1, n), rdtype).at[0].set(v0)
        hess = jnp.zeros((m + 1, m), rdtype)

        def arnoldi(j, carry):
            vs, hess = carry
            w = opvec(vs[j])

            def mgs(k, wh):
                # rows past j are still zero, so their projections vanish
                w, hcol = wh
                hk = jnp.vdot(vs[k], w)
                return w - hk * vs[k], hcol.at[k].set(hk)

            w, hcol = lax.fori_loop(0, m + 1, mgs,
                                    (w, jnp.zeros(m + 1, rdtype)))
            hj1 = jnp.linalg.norm(w)
            vnext = jnp.where(hj1 > _TINY, w / jnp.maximum(hj1, _TINY), 0.0)
            hess = hess.at[:, j].set(hcol).at[j + 1, j].set(hj1)
            return vs.at[j + 1].set(vnext), hess

        vs, hess = lax.fori_loop(0, m, arnoldi, (vs, hess))
        e1 = jnp.zeros(m + 1, rdtype).at[0].set(beta)
        y, *_ = jnp.linalg.lstsq(hess, e1)
        return (vs[:m].T @ y).reshape(shape)  # u-space correction

    def relnorm(r):
        return (_colnorm(r) / bnorm).astype(rdtype)

    def sweep(x, r):
        du = cycle(r.ravel())
        return x + correct(du).astype(rdtype)

    return _refine_loop(sweep, resid, relnorm, x0, rcfg, tol)


# ---------------------------------------------------------------------------
# matrix-level drivers
# ---------------------------------------------------------------------------
def _as_refine_config(refine) -> RefineConfig:
    if isinstance(refine, RefineConfig):
        return refine
    if isinstance(refine, int):
        return RefineConfig(max_sweeps=refine)
    if refine is None:
        return RefineConfig()
    raise TypeError(f"refine must be int | RefineConfig | None: {refine!r}")


def iterative_refine(a, b, cfg: PrecisionConfig | None = None,
                     refine: int | RefineConfig | None = None, *,
                     l=None, col_tol=None, linvs=None) -> RefineResult:
    """Factor once in ``cfg``'s ladder, refine to ``refine.tol``.

    ``a`` is required here (the residual needs it) in the residual
    precision; pass a precomputed ``l`` to skip the factorization.
    Dispatches on ``refine.method``: classic IR or GMRES-IR. The sweep
    residual ``b - A x`` goes through :func:`repro.kernels.ops.residual`
    — the fused Pallas kernel on TPU (or when ``cfg.kernel_impl``
    forces it), the XLA oracle elsewhere. ``col_tol`` gives an (n, k)
    ``b`` per-column tolerances overriding the scalar ``refine.tol``
    (the serve scheduler's per-request accuracy targets). ``linvs``
    reuses cached diagonal-tile inverses across every sweep's pair of
    triangular solves (blocked engine; see ``core.blocked.diag_tri_inv``).
    """
    cfg = cfg or PrecisionConfig()
    rcfg = _as_refine_config(refine)
    rdtype = rcfg.rdtype()
    assert a is not None, "refinement forms residuals b - A x: pass A"
    if l is None:
        l = cholesky_padded(a, cfg)   # solves consume the padded form
    if linvs is None and cfg.engine == "blocked":
        # every sweep runs two triangular passes against the same factor:
        # invert the diagonal leaves once here instead of per sweep
        from repro.core.blocked import diag_tri_inv
        from repro.core.tree import pad_factor
        l = pad_factor(l, cfg.leaf)
        linvs = diag_tri_inv(l, cfg)
    a_r = jnp.asarray(a, rdtype)
    b_r = jnp.asarray(b, rdtype)

    def matvec(x):
        return a_r @ x

    def resid(x):
        return ops.residual(a_r, x, b_r, impl=cfg.kernel_impl)

    def base_solve(r):
        return solve_factored(l, r.astype(l.dtype), cfg,
                              linvs=linvs).astype(rdtype)

    correct = scaled_solve(base_solve)
    # the initial solve is unscaled so refine=0 reproduces cholesky_solve
    x0 = base_solve(b_r)
    run = gmres_operator if rcfg.method == "gmres" else refine_operator
    return run(matvec, correct, b_r, x0, rcfg, resid=resid, tol=col_tol)


def gmres_refine(a, b, cfg: PrecisionConfig | None = None,
                 refine: int | RefineConfig | None = None, *,
                 l=None, col_tol=None, linvs=None) -> RefineResult:
    """GMRES-IR convenience wrapper (``method`` forced to ``"gmres"``)."""
    rcfg = dataclasses.replace(_as_refine_config(refine), method="gmres")
    return iterative_refine(a, b, cfg, rcfg, l=l, col_tol=col_tol,
                            linvs=linvs)
