"""SPD solve / factorization public API built on the tree routines."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.precision import PrecisionConfig
from repro.core.tree import (pad_spd, tree_potrf, tree_trsm_left)


def cholesky(a, cfg: PrecisionConfig | None = None):
    """Lower Cholesky factor via the nested recursive mixed-precision
    algorithm. Handles arbitrary n by identity-padding to the leaf size."""
    cfg = cfg or PrecisionConfig()
    a_p, n = pad_spd(a, cfg.leaf)
    l = tree_potrf(a_p, cfg)
    return l[:n, :n]


def cholesky_solve(a, b, cfg: PrecisionConfig | None = None, *, l=None,
                   refine=None):
    """Solve A x = b for SPD A via L (L^T x) = b with tree solves.

    ``b`` may be (n,) or (n, k). Pass a precomputed ``l`` to reuse a
    factorization (the K-FAC optimizer does this across steps).

    ``refine`` (int sweep count or :class:`repro.core.refine.RefineConfig`)
    runs mixed-precision iterative refinement after the base solve: the
    factorization stays in the cheap ladder while residuals are formed in
    the refinement precision, recovering working-precision accuracy.
    Requires ``a``. Returns just ``x`` (use :func:`refine_solve` for the
    full :class:`~repro.core.refine.RefineResult`).

    NOTE: with ``refine`` the result comes back in the RESIDUAL precision
    (f32, or f64 under x64), NOT ``b.dtype`` — casting a refined solution
    back to an f16/bf16 RHS dtype would throw away every digit the sweeps
    just paid for. Callers that need the narrow dtype (none in-tree: the
    K-FAC whitening path and the serve engine both consume the wide
    result) must downcast explicitly.
    """
    cfg = cfg or PrecisionConfig()
    if refine is not None:
        return refine_solve(a, b, cfg, refine=refine, l=l).x

    vec = b.ndim == 1
    if vec:
        b = b[:, None]
    n = b.shape[0]
    if l is None:
        l = cholesky(a, cfg)
    npad = -(-n // cfg.leaf) * cfg.leaf
    if npad != n:
        lp = jnp.zeros((npad, npad), l.dtype)
        lp = lp.at[:n, :n].set(l)
        lp = lp.at[jnp.arange(n, npad), jnp.arange(n, npad)].set(1.0)
        bp = jnp.zeros((npad, b.shape[1]), b.dtype)
        bp = bp.at[:n].set(b)
    else:
        lp, bp = l, b
    y = tree_trsm_left(bp, lp, cfg, trans=False)
    x = tree_trsm_left(y, lp, cfg, trans=True)
    x = x[:n]
    return x[:, 0] if vec else x


def solve_factored(l, b, cfg: PrecisionConfig | None = None):
    """Two triangular tree-solves with an existing factor (hot K-FAC path)."""
    return cholesky_solve(None, b, cfg, l=l)


def refine_solve(a, b, cfg: PrecisionConfig | None = None, *,
                 refine=None, l=None, col_tol=None):
    """Accuracy-targeted solve: cheap-ladder factorization + iterative
    refinement. Returns the full :class:`~repro.core.refine.RefineResult`
    (solution, residual history, sweeps, converged — per column for an
    (n, k) ``b``). ``refine`` is an int sweep bound or a
    :class:`~repro.core.refine.RefineConfig` (choosing classic IR or
    GMRES-IR); ``None`` means the default 5-sweep IR. ``col_tol`` sets
    per-column tolerances for multi-RHS blocks (the serve scheduler's
    per-request accuracy targets).
    """
    from repro.core import refine as _refine  # circular-import guard
    return _refine.iterative_refine(a, b, cfg, refine, l=l,
                                    col_tol=col_tol)


def logdet(l):
    """log det(A) = 2 sum(log diag(L)) — used by the GP example."""
    return 2.0 * jnp.sum(jnp.log(jnp.diagonal(l)))


@functools.partial(jax.jit, static_argnames=("cfg",))
def cholesky_jit(a, cfg: PrecisionConfig):
    return cholesky(a, cfg)


@functools.partial(jax.jit, static_argnames=("cfg",))
def cholesky_solve_jit(a, b, cfg: PrecisionConfig):
    return cholesky_solve(a, b, cfg)
