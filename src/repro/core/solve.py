"""SPD solve / factorization public API with engine dispatch.

``PrecisionConfig.engine`` selects the execution engine behind every
entry point here:

* ``"blocked"`` (default) — the flat in-place tile schedule driven by
  the static precision plan (:mod:`repro.core.plan`,
  :mod:`repro.core.blocked`): copy-free, one fused panel-update kernel
  per leaf panel, no recursion.
* ``"tree"`` — the paper's nested recursion (:mod:`repro.core.tree`),
  kept as the reference oracle the equivalence suite checks the blocked
  engine against.
* ``"auto"`` — resolved here, at factor time, against the tuning
  database (:mod:`repro.tune`, docs/TUNING.md): the measured winner for
  the problem size on this backend, falling back to ``"blocked"`` when
  no database entry applies.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.blocked import blocked_potrf, blocked_trsm_left, diag_tri_inv
from repro.core.precision import PrecisionConfig
from repro.core.tree import (pad_factor, pad_spd, tree_potrf, tree_trsm_left)


def _autoresolve(cfg: PrecisionConfig, n: int) -> PrecisionConfig:
    """Resolve ``engine="auto"`` via the tuning DB (no-op otherwise)."""
    if cfg.engine != "auto":
        return cfg
    from repro import tune  # local: tune is a consumer of this module
    return tune.resolve_cfg(cfg, n)


def _potrf(a_padded, cfg: PrecisionConfig):
    if cfg.engine == "blocked":
        return blocked_potrf(a_padded, cfg)
    return tree_potrf(a_padded, cfg)


def _trsm_left(b, l, cfg: PrecisionConfig, *, trans, linvs=None):
    if cfg.engine == "blocked":
        return blocked_trsm_left(b, l, cfg, trans=trans, linvs=linvs)
    return tree_trsm_left(b, l, cfg, trans=trans)


def cholesky(a, cfg: PrecisionConfig | None = None):
    """Lower Cholesky factor via the mixed-precision engine selected by
    ``cfg.engine``. Handles arbitrary n by identity-padding to the leaf
    size."""
    cfg = cfg or PrecisionConfig()
    n = a.shape[-1]
    return cholesky_padded(a, cfg)[:n, :n]


def cholesky_padded(a, cfg: PrecisionConfig | None = None):
    """Leaf-padded lower factor (identity tail, shape a multiple of
    ``cfg.leaf``) — the form the solve paths and factor caches consume
    directly, skipping the trim-then-re-pad round trip.
    ``cholesky_padded(a)[:n, :n] == cholesky(a)`` exactly."""
    cfg = cfg or PrecisionConfig()
    a_p, _ = pad_spd(jnp.asarray(a), cfg.leaf)
    return _potrf(a_p, _autoresolve(cfg, a_p.shape[-1]))


def cholesky_solve(a, b, cfg: PrecisionConfig | None = None, *, l=None,
                   refine=None, linvs=None):
    """Solve A x = b for SPD A via L (L^T x) = b.

    ``b`` may be (n,) or (n, k). Pass a precomputed ``l`` to reuse a
    factorization (the K-FAC optimizer does this across steps); ``l``
    may be either the tight (n, n) factor or the leaf-padded factor
    (``pad_factor``) — the serve engine caches the padded form so
    non-multiple-of-leaf solves skip the re-padding writes. ``linvs``
    additionally reuses the blocked engine's per-diagonal-tile inverses
    (:func:`repro.core.blocked.diag_tri_inv`), which both triangular
    sweeps share.

    ``refine`` (int sweep count or :class:`repro.core.refine.RefineConfig`)
    runs mixed-precision iterative refinement after the base solve: the
    factorization stays in the cheap ladder while residuals are formed in
    the refinement precision, recovering working-precision accuracy.
    Requires ``a``. Returns just ``x`` (use :func:`refine_solve` for the
    full :class:`~repro.core.refine.RefineResult`).

    NOTE: with ``refine`` the result comes back in the RESIDUAL precision
    (f32, or f64 under x64), NOT ``b.dtype`` — casting a refined solution
    back to an f16/bf16 RHS dtype would throw away every digit the sweeps
    just paid for. Callers that need the narrow dtype (none in-tree: the
    K-FAC whitening path and the serve engine both consume the wide
    result) must downcast explicitly.
    """
    cfg = cfg or PrecisionConfig()
    if refine is not None:
        return refine_solve(a, b, cfg, refine=refine, l=l, linvs=linvs).x

    vec = b.ndim == 1
    if vec:
        b = b[:, None]
    n = b.shape[0]
    npad = -(-n // cfg.leaf) * cfg.leaf
    cfg = _autoresolve(cfg, npad)
    if l is None:
        lp = cholesky_padded(a, cfg)
    elif l.shape[-1] == npad:
        lp = l                      # already padded (serve factor cache)
    else:
        lp = pad_factor(l, cfg.leaf)
    if npad == n:
        bp = b
    else:
        bp = jnp.zeros((npad, b.shape[1]), b.dtype).at[:n].set(b)
    if cfg.engine == "blocked" and linvs is None:
        linvs = diag_tri_inv(lp, cfg)
    y = _trsm_left(bp, lp, cfg, trans=False, linvs=linvs)
    x = _trsm_left(y, lp, cfg, trans=True, linvs=linvs)
    x = x[:n]
    return x[:, 0] if vec else x


def solve_factored(l, b, cfg: PrecisionConfig | None = None, *, linvs=None):
    """Two triangular solves with an existing factor (hot K-FAC path).
    ``linvs`` reuses cached diagonal-tile inverses (blocked engine)."""
    return cholesky_solve(None, b, cfg, l=l, linvs=linvs)


def refine_solve(a, b, cfg: PrecisionConfig | None = None, *,
                 refine=None, l=None, col_tol=None, linvs=None):
    """Accuracy-targeted solve: cheap-ladder factorization + iterative
    refinement. Returns the full :class:`~repro.core.refine.RefineResult`
    (solution, residual history, sweeps, converged — per column for an
    (n, k) ``b``). ``refine`` is an int sweep bound or a
    :class:`~repro.core.refine.RefineConfig` (choosing classic IR or
    GMRES-IR); ``None`` means the default 5-sweep IR. ``col_tol`` sets
    per-column tolerances for multi-RHS blocks (the serve scheduler's
    per-request accuracy targets). ``l``/``linvs`` reuse a cached factor
    and its diagonal-tile inverses across sweeps and requests.
    """
    from repro.core import refine as _refine  # circular-import guard
    if cfg is not None and cfg.engine == "auto":
        npad = -(-b.shape[0] // cfg.leaf) * cfg.leaf
        cfg = _autoresolve(cfg, npad)
    return _refine.iterative_refine(a, b, cfg, refine, l=l,
                                    col_tol=col_tol, linvs=linvs)


def logdet(l):
    """log det(A) = 2 sum(log diag(L)) — used by the GP example."""
    return 2.0 * jnp.sum(jnp.log(jnp.diagonal(l)))


@functools.partial(jax.jit, static_argnames=("cfg",))
def cholesky_jit(a, cfg: PrecisionConfig):
    return cholesky(a, cfg)


@functools.partial(jax.jit, static_argnames=("cfg",))
def cholesky_solve_jit(a, b, cfg: PrecisionConfig):
    return cholesky_solve(a, b, cfg)
