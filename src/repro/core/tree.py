"""Nested recursive Cholesky (paper Algs. 1-3) with layered precision.

The three routines are mutually recursive and unroll at *trace time*
(all shapes static under jit) — the runtime dispatch the paper implements
with Julia multiple-dispatch becomes a static DAG of mixed-precision
GEMMs + Pallas leaf kernels that XLA schedules.

Precision rule (uniform; docs/ARCHITECTURE.md, "Execution engines"): every tree node at recursion
``level`` computes its GEMM in ``cfg.levels[min(level, -1)]``; every
recursive call increments ``level``; leaves use the node's level dtype.
Narrow dtypes (f16) get the paper's per-block quantization wrapped around
each GEMM, with the dequantization scale fused into the qgemm epilogue.

``storage_rounding`` reproduces the paper's tree data structure numerics:
each updated off-diagonal block is rounded to its level's storage dtype
after the update, exactly as if it lived in the low-precision tree node.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.precision import PrecisionConfig
from repro.core.quantize import quant_block
from repro.kernels import ops


def _round_to(x, name: str, cfg: PrecisionConfig):
    """Round ``x`` to the level's storage dtype, keep container dtype.

    This simulates the paper's recursive data structure, where each
    off-diagonal block is *stored* in its level's precision: numerics are
    identical to low-precision storage while the container stays dense.
    For narrow dtypes the block is stored *scaled* (paper Fig. 3: the tree
    node carries its per-block alpha), so storage never overflows.
    """
    if not cfg.storage_rounding:
        return x
    from repro.core.quantize import storage_round
    return storage_round(x, name, cfg.quantize)


def _sym_from_lower(a):
    low = jnp.tril(a)
    return low + jnp.tril(a, -1).T


def tree_potrf(a, cfg: PrecisionConfig, *, level: int = 0):
    """Lower Cholesky factor of SPD ``a`` (paper Alg. 1). Reads the lower
    triangle only; returns L with zeroed upper triangle. ``a.shape[-1]``
    must be a multiple of ``cfg.leaf`` (use :func:`pad_spd` otherwise)."""
    n = a.shape[-1]
    assert a.shape == (n, n), a.shape
    if n <= cfg.leaf:
        name = cfg.name_at(level)
        leaf = _round_to(_sym_from_lower(a), name, cfg)
        out = ops.potrf(leaf.astype(cfg.high_dtype), impl=cfg.kernel_impl)
        return _round_to(out.astype(a.dtype), name, cfg)
    n1 = cfg.split(n)
    a11, a21, a22 = a[:n1, :n1], a[n1:, :n1], a[n1:, n1:]
    l11 = tree_potrf(a11, cfg, level=level + 1)
    l21 = tree_trsm(a21, l11, cfg, level=level)
    a22 = tree_syrk(a22, l21, alpha=-1.0, beta=1.0, cfg=cfg, level=level)
    l22 = tree_potrf(a22, cfg, level=level + 1)
    n2 = n - n1
    top = jnp.concatenate([l11, jnp.zeros((n1, n2), a.dtype)], axis=1)
    bot = jnp.concatenate([l21, l22], axis=1)
    return jnp.concatenate([top, bot], axis=0)


def tree_trsm(b, l, cfg: PrecisionConfig, *, level: int = 0):
    """X = B L^{-T} (right, lower, transposed — paper Alg. 2).

    ``b``: (m, n) panel, ``l``: (n, n) lower-triangular. Recursion splits
    the *n* (triangle) dimension; the m dimension streams through the leaf
    kernel's grid.
    """
    m, n = b.shape
    assert l.shape == (n, n), (b.shape, l.shape)
    name = cfg.name_at(level)
    if n <= cfg.leaf:
        x = ops.trsm(_round_to(b, name, cfg).astype(cfg.high_dtype),
                     l.astype(cfg.high_dtype),
                     side="right", trans=True, impl=cfg.kernel_impl)
        return _round_to(x.astype(b.dtype), name, cfg)
    n1 = cfg.split(n)
    l11, l21, l22 = l[:n1, :n1], l[n1:, :n1], l[n1:, n1:]
    b1 = tree_trsm(b[:, :n1], l11, cfg, level=level + 1)
    # B2 <- B2 - B1 L21^T  (the exposed GEMM, low precision + quantization)
    q = cfg.needs_quant(level)
    b1q, s1 = quant_block(b1, name, q)
    l21q, s2 = quant_block(l21, name, q)
    b2 = ops.qgemm(b1q, l21q, scale=-(s1 * s2), c=b[:, n1:], beta=1.0,
                   trans_b=True, out_dtype=b.dtype, impl=cfg.kernel_impl)
    b2 = _round_to(b2, name, cfg)
    b2 = tree_trsm(b2, l22, cfg, level=level + 1)
    return jnp.concatenate([b1, b2], axis=1)


def tree_syrk(c, a, *, alpha=1.0, beta=1.0, cfg: PrecisionConfig,
              level: int = 0):
    """C <- beta C + alpha A A^T on the lower triangle (paper Alg. 3 — the
    first recursive accelerator SYRK). ``c``: (n, n), ``a``: (n, k)."""
    n = c.shape[-1]
    k = a.shape[-1]
    assert c.shape == (n, n) and a.shape == (n, k), (c.shape, a.shape)
    name = cfg.name_at(level)
    if n <= cfg.leaf:
        q = cfg.needs_quant(level)
        aq, s = quant_block(_round_to(a, name, cfg), name, q)
        out = ops.syrk(c, aq, scale=alpha * s * s, beta=beta,
                       impl=cfg.kernel_impl)
        return _round_to(out, name, cfg)
    n1 = cfg.split(n)
    c11 = tree_syrk(c[:n1, :n1], a[:n1], alpha=alpha, beta=beta, cfg=cfg,
                    level=level + 1)
    # C21 <- beta C21 + alpha A2 A1^T  (the exposed GEMM)
    q = cfg.needs_quant(level)
    a2q, s2 = quant_block(a[n1:], name, q)
    a1q, s1 = quant_block(a[:n1], name, q)
    c21 = ops.qgemm(a2q, a1q, scale=alpha * s1 * s2, c=c[n1:, :n1],
                    beta=beta, trans_b=True, out_dtype=c.dtype,
                    impl=cfg.kernel_impl)
    c21 = _round_to(c21, name, cfg)
    c22 = tree_syrk(c[n1:, n1:], a[n1:], alpha=alpha, beta=beta, cfg=cfg,
                    level=level + 1)
    n2 = n - n1
    top = jnp.concatenate([c11, jnp.zeros((n1, n2), c.dtype)], axis=1)
    bot = jnp.concatenate([c21, c22], axis=1)
    return jnp.concatenate([top, bot], axis=0)


def tree_trsm_left(b, l, cfg: PrecisionConfig, *, trans: bool,
                   level: int = 0):
    """Left-side solves needed by cholesky_solve:

    trans=False : X = L^{-1} B      (forward substitution)
    trans=True  : X = L^{-T} B      (back substitution)
    """
    n, m = b.shape
    assert l.shape == (n, n), (b.shape, l.shape)
    name = cfg.name_at(level)
    if n <= cfg.leaf:
        x = ops.trsm(_round_to(b, name, cfg).astype(cfg.high_dtype),
                     l.astype(cfg.high_dtype),
                     side="left", trans=trans, impl=cfg.kernel_impl)
        return _round_to(x.astype(b.dtype), name, cfg)
    n1 = cfg.split(n)
    l11, l21, l22 = l[:n1, :n1], l[n1:, :n1], l[n1:, n1:]
    q = cfg.needs_quant(level)
    if not trans:
        # y1 = L11^{-1} B1 ; B2 -= L21 y1 ; y2 = L22^{-1} B2
        y1 = tree_trsm_left(b[:n1], l11, cfg, trans=False, level=level + 1)
        l21q, s1 = quant_block(l21, name, q)
        y1q, s2 = quant_block(y1, name, q)
        b2 = ops.qgemm(l21q, y1q, scale=-(s1 * s2), c=b[n1:], beta=1.0,
                       out_dtype=b.dtype, impl=cfg.kernel_impl)
        b2 = _round_to(b2, name, cfg)
        y2 = tree_trsm_left(b2, l22, cfg, trans=False, level=level + 1)
        return jnp.concatenate([y1, y2], axis=0)
    # trans: x2 = L22^{-T} B2 ; B1 -= L21^T x2 ; x1 = L11^{-T} B1
    x2 = tree_trsm_left(b[n1:], l22, cfg, trans=True, level=level + 1)
    l21tq, s1 = quant_block(l21.T, name, q)
    x2q, s2 = quant_block(x2, name, q)
    b1 = ops.qgemm(l21tq, x2q, scale=-(s1 * s2), c=b[:n1], beta=1.0,
                   out_dtype=b.dtype, impl=cfg.kernel_impl)
    b1 = _round_to(b1, name, cfg)
    x1 = tree_trsm_left(b1, l11, cfg, trans=True, level=level + 1)
    return jnp.concatenate([x1, x2], axis=0)


def _tail_scale(diag_vals):
    """Power-of-two scale matching the matrix's diagonal magnitude.

    The padding tail must sit at the DIAGONAL'S magnitude, not at 1.0:
    a unit tail that shares a leaf tile with a large diagonal quantizes
    to zero under the int8/f16 per-block storage rounding (singular
    trailing block, NaN factor — the documented tree-oracle bug). A
    power of two keeps the scale exactly representable, so the same
    value is recovered bit-identically from either the matrix's diagonal
    (:func:`pad_spd`) or the factor's row norms (:func:`pad_factor`),
    and ``sqrt`` of it is the same correctly-rounded float on both
    paths.
    """
    m = jnp.maximum(jnp.mean(diag_vals.astype(jnp.float32)), 1e-30)
    # ldexp, not exp2: XLA's exp2 is not exact at integer exponents
    return jnp.ldexp(jnp.float32(1.0),
                     jnp.round(jnp.log2(m)).astype(jnp.int32))


def _pad_diag_tail(a, npad: int, tail):
    """Embed ``a`` in an ``npad x npad`` zero matrix whose diagonal tail
    is ``tail`` — the shared body of :func:`pad_spd` / :func:`pad_factor`."""
    n = a.shape[-1]
    out = jnp.zeros((npad, npad), a.dtype)
    out = out.at[:n, :n].set(a)
    idx = jnp.arange(n, npad)
    return out.at[idx, idx].set(jnp.asarray(tail, a.dtype))


def pad_spd(a, leaf: int):
    """Pad an SPD matrix to a multiple of ``leaf`` with a diagonal tail
    scaled to the matrix's diagonal magnitude (keeps SPD-ness exactly;
    the factor of the tail block is ``sqrt(tail) * I``). The scaling —
    rather than a fixed identity tail — keeps the tail representable
    under per-block storage quantization next to a large diagonal."""
    n = a.shape[-1]
    npad = -(-n // leaf) * leaf
    if npad == n:
        return a, n
    return _pad_diag_tail(a, npad, _tail_scale(jnp.diagonal(a))), n


def pad_factor(l, leaf: int):
    """Pad a Cholesky factor to a multiple of ``leaf`` the way
    :func:`pad_spd` pads the matrix. The tail scale is recovered from
    the factor itself (``mean of row sums of squares == mean diagonal of
    A``, rounded to the same power of two), so
    ``pad_factor(cholesky(a)[:n, :n]) == cholesky(pad_spd(a))`` exactly —
    solve paths re-pad cached factors through here instead of rebuilding
    the three ``.at[]`` writes inline on every call."""
    n = l.shape[-1]
    npad = -(-n // leaf) * leaf
    if npad == n:
        return l
    tail = jnp.sqrt(_tail_scale(jnp.sum(l.astype(jnp.float32) ** 2, axis=1)))
    return _pad_diag_tail(l, npad, tail)
