"""Per-block quantization / dequantization (paper §III-D).

Before each low-precision GEMM the operand block is rescaled by

    alpha = max(1, ||B||_inf / R_max)

so every value fits the narrow format's range; the GEMM epilogue multiplies
the f32 accumulator by the product of operand scales (dequantization).
For bf16/f32 levels the exponent range matches f32 and the scale is
statically 1 (no absmax pass is emitted).

The same primitive backs the int8 error-feedback gradient compressor in
``repro.train.compress`` — one quantizer, two uses (solver + distributed
training); docs/ARCHITECTURE.md, "Precision ladder".
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.precision import DTYPES, NARROW, RMAX


def quant_block(x, level_name: str, enable: bool = True):
    """Cast ``x`` to the level's dtype with range-safe scaling.

    Returns ``(x_q, alpha)`` such that ``x ~= x_q.astype(f32) * alpha``.
    ``alpha`` is a traced f32 scalar (1.0 when no rescale was needed).

    int8 (beyond-paper ladder level) always scales: alpha = absmax/127,
    values rounded into [-127, 127] — the paper's Fig. 3 scheme taken to
    the MXU's double-rate integer path.
    """
    dtype = DTYPES[level_name]
    if level_name == "int8":
        amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
        alpha = jnp.maximum(amax, jnp.float32(1e-30)) / jnp.float32(127.0)
        q = jnp.clip(jnp.round(x.astype(jnp.float32) / alpha), -127, 127)
        return q.astype(dtype), alpha
    if not enable or level_name not in NARROW:
        return x.astype(dtype), jnp.float32(1.0)
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    alpha = jnp.maximum(jnp.float32(1.0), amax / jnp.float32(RMAX[level_name]))
    return (x / alpha.astype(x.dtype)).astype(dtype), alpha


def dequant(x, alpha):
    return x.astype(jnp.float32) * alpha


def storage_round(x, level_name: str, quantize: bool = True):
    """Round ``x``'s VALUES to ``level_name``'s grid, keep container dtype.

    This is the value-level form of :func:`quant_block`: the result lives
    in ``x.dtype`` but carries exactly the information a ``level_name``
    store would (for narrow formats the block is rounded *scaled*, i.e.
    ``q * alpha``, so storage never overflows — unless ``quantize`` is
    off, reproducing the paper's overflow ablation). Both the tree's
    ``_round_to`` and the flat blocked executor go through here so the
    two engines share one definition of "stored at level ``name``".
    """
    dt = DTYPES[level_name]
    if jnp.dtype(dt) == x.dtype:
        return x
    if level_name == "int8" or (level_name in NARROW and quantize):
        xq, alpha = quant_block(x, level_name, True)
        return xq.astype(x.dtype) * alpha.astype(x.dtype)
    return x.astype(dt).astype(x.dtype)


def quant_int8(x):
    """Symmetric int8 quantization with per-tensor scale (gradient
    compression path). Returns (q, scale) with x ~= q * scale."""
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    scale = jnp.maximum(amax, jnp.float32(1e-30)) / jnp.float32(127.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequant_int8(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)
