"""Static per-tile precision plan (the flat answer to the recursion).

The tree solver (:mod:`repro.core.tree`) assigns precision implicitly:
every recursion node computes its exposed GEMMs in ``levels[min(level,
-1)]`` and each leaf rounds its tile at the level the recursion happens
to reach. That assignment is a pure function of the *geometry* — matrix
size, leaf size, bisection rule — so it can be computed once, with no
array ops, as a per-tile table. This module walks the same recursion on
index ranges only and emits, for every ``leaf x leaf`` tile ``(i, j)``:

* ``level``    — the recursion level of the potrf node whose split
  separates ``i`` from ``j`` (for diagonal tiles: the depth of the path
  down to the singleton leaf). This is the level of every GEMM the tree
  exposes on the tile, i.e. its *compute* precision — the paper's
  "precision rises toward the diagonal" map.
* ``store_level`` — the (deeper, >= ``level``) recursion level at which
  the tree's TRSM leaf finally rounds the tile for storage.
* ``quantize`` — whether the paper's per-block quantization applies at
  the tile's compute level.

:func:`build_plan` is cached per ``(n, cfg)``; the flat blocked executor
(:mod:`repro.core.blocked`) looks tiles up here instead of re-deriving
precision by recursing, and :meth:`PrecisionPlan.describe` renders the
map for humans (README "Execution engines").
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core.dtypes import BYTES, WIRE_DTYPE
from repro.core.precision import DTYPES, NARROW, PrecisionConfig


def _eff(name: str, container: str) -> str:
    """Effective precision of a value rounded to ``name`` inside a
    ``container``-dtype array (the CPU oracles keep narrow values in
    wide containers): the narrower of the two."""
    return name if BYTES[name] < BYTES[container] else container


@dataclasses.dataclass(frozen=True)
class TileInfo:
    """Static precision assignment of one leaf tile."""

    level: int          # compute level (GEMM precision of the tile)
    name: str           # dtype name at the compute level
    store_level: int    # level whose dtype the tree stores the tile in
    store_name: str     # dtype name at the storage level
    quantize: bool      # per-block quantization applies at compute level

    @property
    def dtype(self):
        return DTYPES[self.name]

    @property
    def store_dtype(self):
        return DTYPES[self.store_name]


def _needs_quant(name: str, cfg: PrecisionConfig) -> bool:
    """Mirror of ``tree._round_to`` / ``cfg.needs_quant`` gating."""
    if name == "int8":
        return True
    return cfg.quantize and name in NARROW


def _split_tiles(nt: int) -> int:
    """cfg.split in tile units: leaf-aligned bisection point."""
    return max(1, nt // 2)


class PrecisionPlan:
    """Per-tile precision table for an ``n x n`` factorization.

    ``levels``/``store_levels`` are symmetric ``(T, T)`` int arrays
    (``T = n // leaf``); only the lower triangle is meaningful to the
    executor but the mirror keeps lookups order-free.
    """

    def __init__(self, n: int, cfg: PrecisionConfig):
        assert n % cfg.leaf == 0 and n > 0, (n, cfg.leaf)
        self.n = n
        self.cfg = cfg
        self.leaf = cfg.leaf
        self.ntiles = n // cfg.leaf
        T = self.ntiles
        comp = np.zeros((T, T), np.int32)
        store = np.zeros((T, T), np.int32)
        self._walk_potrf(comp, store, 0, T, 0)
        # mirror so (i, j) and (j, i) agree
        il = np.tril_indices(T, -1)
        comp[il[1], il[0]] = comp[il]
        store[il[1], il[0]] = store[il]
        self.levels = comp
        self.store_levels = store

    # -- construction (mirrors tree.py's recursion on index ranges) --------
    def _walk_potrf(self, comp, store, lo, hi, level):
        if hi - lo == 1:
            comp[lo, lo] = store[lo, lo] = level
            return
        mid = lo + _split_tiles(hi - lo)
        self._walk_potrf(comp, store, lo, mid, level + 1)
        # A21 block: every exposed GEMM runs at this node's level ...
        comp[mid:hi, lo:mid] = level
        # ... while the TRSM leaf that finally stores each column sits
        # deeper, at level + (column bisection depth):
        self._walk_trsm(store, mid, hi, lo, mid, level)
        self._walk_potrf(comp, store, mid, hi, level + 1)

    def _walk_trsm(self, store, rlo, rhi, clo, chi, level):
        if chi - clo == 1:
            store[rlo:rhi, clo] = level
            return
        cmid = clo + _split_tiles(chi - clo)
        self._walk_trsm(store, rlo, rhi, clo, cmid, level + 1)
        self._walk_trsm(store, rlo, rhi, cmid, chi, level + 1)

    # -- lookups -----------------------------------------------------------
    def level(self, i: int, j: int) -> int:
        return int(self.levels[i, j])

    def name(self, i: int, j: int) -> str:
        return self.cfg.name_at(self.level(i, j))

    def store_name(self, i: int, j: int) -> str:
        return self.cfg.name_at(int(self.store_levels[i, j]))

    def quant(self, i: int, j: int) -> bool:
        return _needs_quant(self.name(i, j), self.cfg)

    def tile(self, i: int, j: int) -> TileInfo:
        lv, sv = self.level(i, j), int(self.store_levels[i, j])
        name = self.cfg.name_at(lv)
        return TileInfo(level=lv, name=name, store_level=sv,
                        store_name=self.cfg.name_at(sv),
                        quantize=_needs_quant(name, self.cfg))

    def subplan(self, lo: int, hi: int) -> "PrecisionPlan":
        """Tile-square view ``[lo, hi)`` of this plan (shared tables).

        The returned object answers every lookup with the PARENT plan's
        levels for those tiles, so an executor running on a sub-block
        (the distributed solver's redundant diagonal factorization)
        computes each tile at the precision the GLOBAL recursion assigns
        it — not the precision a fresh size-``hi - lo`` recursion would.
        """
        assert 0 <= lo < hi <= self.ntiles, (lo, hi, self.ntiles)
        sub = object.__new__(PrecisionPlan)
        sub.n = (hi - lo) * self.leaf
        sub.cfg = self.cfg
        sub.leaf = self.leaf
        sub.ntiles = hi - lo
        sub.levels = self.levels[lo:hi, lo:hi]
        sub.store_levels = self.store_levels[lo:hi, lo:hi]
        return sub

    def panel_meta(self, p: int) -> "PanelMeta":
        """Static metadata for the fused panel update at panel ``p``:
        storage names/quant flags for the trailing row tiles of column
        ``p`` and compute names/quant flags for every trailing pair."""
        cfg = self.cfg
        rows = range(p + 1, self.ntiles)
        store_names = tuple(self.store_name(i, p) for i in rows)
        store_quants = tuple(_needs_quant(nm, cfg) for nm in store_names)
        pair_names = tuple(tuple(self.name(i, j) for j in rows)
                           for i in rows)
        pair_quants = tuple(tuple(_needs_quant(nm, cfg) for nm in row)
                            for row in pair_names)
        return PanelMeta(store_names, store_quants, pair_names, pair_quants)

    # -- audit lookup tables (consumed by repro.audit.conformance) ---------
    def panel_dot_flops(self, p: int, container: str | None = None) -> dict:
        """Expected GEMM FLOPs by *effective* dtype name for the blocked
        executor's panel-``p`` update: one ``2 b^3`` TRSM dot per
        trailing row tile at its storage precision, one ``2 b^3``
        trailing dot per pair tile (incl. diagonal) at its compute
        precision. ``container`` is the carrying array dtype (default:
        the ladder's high name)."""
        cn = container or self.cfg.high_name
        b = self.leaf
        f = 2.0 * float(b) ** 3
        out: dict[str, float] = {}
        rows = range(p + 1, self.ntiles)
        for i in rows:
            nm = _eff(self.store_name(i, p), cn)
            out[nm] = out.get(nm, 0.0) + f
        for i in rows:
            for j in range(p + 1, i + 1):
                nm = _eff(self.name(i, j), cn)
                out[nm] = out.get(nm, 0.0) + f
        return out

    def panel_round_elems(self, p: int, container: str | None = None) -> dict:
        """Expected value-rounding events (elements, by target dtype
        name) the blocked executor emits for panel ``p``'s update:

        * 2 per trailing row tile at its storage name (the incoming
          block pre-TRSM and the solved L21 tile),
        * one full-column re-round per distinct trailing pair dtype
          (the executor's ``lq`` cache),
        * one per trailing pair tile at its compute name (the rounded
          partial sum).

        Rounds onto the container dtype itself are value no-ops and
        emit no event."""
        cn = container or self.cfg.high_name
        if not self.cfg.storage_rounding:
            return {}
        b = self.leaf
        out: dict[str, int] = {}
        rows = range(p + 1, self.ntiles)
        for i in rows:
            nm = self.store_name(i, p)
            if nm != cn:
                out[nm] = out.get(nm, 0) + 2 * b * b
        pair_names = {self.name(i, j) for i in rows
                      for j in range(p + 1, i + 1)}
        nt = len(rows)
        for nm in pair_names:
            if nm != cn:
                out[nm] = out.get(nm, 0) + nt * b * b
        for i in rows:
            for j in range(p + 1, i + 1):
                nm = self.name(i, j)
                if nm != cn:
                    out[nm] = out.get(nm, 0) + b * b
        return out

    def diag_round_elems(self, p: int, container: str | None = None) -> dict:
        """Expected rounding events for panel ``p``'s diagonal tile (the
        symmetrized input block and the POTRF output, both rounded at
        the tile's compute name)."""
        cn = container or self.cfg.high_name
        if not self.cfg.storage_rounding:
            return {}
        nm = self.name(p, p)
        b = self.leaf
        return {nm: 2 * b * b} if nm != cn else {}

    def expected_dot_flops(self, container: str | None = None) -> dict:
        """Whole-factorization GEMM FLOPs by effective dtype name."""
        out: dict[str, float] = {}
        for p in range(self.ntiles - 1):
            for nm, v in self.panel_dot_flops(p, container).items():
                out[nm] = out.get(nm, 0.0) + v
        return out

    def expected_round_elems(self, container: str | None = None) -> dict:
        """Whole-factorization rounding events by target dtype name."""
        out: dict[str, int] = {}
        for p in range(self.ntiles):
            for part in (self.diag_round_elems(p, container),
                         self.panel_round_elems(p, container)
                         if p < self.ntiles - 1 else {}):
                for nm, v in part.items():
                    out[nm] = out.get(nm, 0) + v
        return out

    # -- census hooks ------------------------------------------------------
    def level_counts(self) -> dict:
        """Lower-triangle tile count per compute dtype name."""
        counts: dict[str, int] = {}
        for i in range(self.ntiles):
            for j in range(i + 1):
                nm = self.name(i, j)
                counts[nm] = counts.get(nm, 0) + 1
        return counts

    def lowp_tile_fraction(self, names=("f16", "bf16", "int8")) -> float:
        counts = self.level_counts()
        total = sum(counts.values())
        low = sum(v for k, v in counts.items() if k in names)
        return low / total if total else 0.0

    def describe(self) -> str:
        """Human-readable tile map + census (README example)."""
        short = {"int8": "i8 ", "f16": "h16", "bf16": "b16", "f32": "f32",
                 "f64": "f64"}
        lines = [f"PrecisionPlan(n={self.n}, leaf={self.leaf}, "
                 f"tiles={self.ntiles}x{self.ntiles}, "
                 f"ladder={self.cfg.describe()})"]
        counts = self.level_counts()
        lines.append("  tiles: " + "  ".join(
            f"{k}={v}" for k, v in sorted(counts.items())))
        lines.append(f"  low-precision tile fraction: "
                     f"{self.lowp_tile_fraction():.2f}")
        for i in range(self.ntiles):
            row = " ".join(short.get(self.name(i, j), self.name(i, j))
                           for j in range(i + 1))
            lines.append("  " + row)
        return "\n".join(lines)

    def __repr__(self):
        return (f"PrecisionPlan(n={self.n}, leaf={self.leaf}, "
                f"ladder={self.cfg.describe()})")


@dataclasses.dataclass(frozen=True)
class PanelMeta:
    """Hashable (jit-static) per-panel metadata for the panel kernel."""

    store_names: tuple          # per trailing row tile of the panel
    store_quants: tuple
    pair_names: tuple           # [i][j] compute name of trailing pair
    pair_quants: tuple


class ShardedPlan:
    """Block-row partition of a :class:`PrecisionPlan` over ``nshards``.

    The distributed solver (:mod:`repro.core.distributed`) lays the
    global matrix out in 1-D block rows: shard ``s`` owns tile rows
    ``[s*tps, (s+1)*tps)`` with ``tps = ntiles // nshards``, and panel
    ``j`` is the j-th ``(w, w)`` block column, ``w = n // nshards``.
    This view answers the three questions that layout asks of the
    precision map, all statically (pure numpy, no array ops):

    * :meth:`diag_plan` — the tile-square sub-plan of panel ``j``'s
      diagonal block, so the redundant local factorization computes each
      tile at its GLOBAL precision (see :meth:`PrecisionPlan.subplan`).
    * :meth:`store_codes` / :attr:`names` — each shard's block-row slice
      of the per-tile STORAGE map for panel ``j``, as an int32 code
      table the (SPMD, trace-once) local executor indexes with its
      traced shard id.
    * :meth:`comm_level` / :meth:`comm_name` — the precision of panel
      ``j``'s collective: the coarsest compute level any trailing
      consumer of the gathered panel runs at. Early panels (far corner
      still in play) communicate at the ladder's coarse level — the
      paper's per-block quantization applied to the all-gather — while
      panels near the diagonal, whose every consumer computes at a fine
      level, are gathered losslessly. "Precision rises toward the
      diagonal", applied to collectives.
    """

    def __init__(self, plan: PrecisionPlan, nshards: int):
        assert nshards >= 1 and plan.ntiles % nshards == 0, (
            f"ntiles={plan.ntiles} must divide into nshards={nshards}")
        self.plan = plan
        self.cfg = plan.cfg
        self.nshards = nshards
        self.tps = plan.ntiles // nshards       # tile rows per shard
        self.panel_width = plan.n // nshards
        #: static code alphabet for store_codes tables (sorted dtype
        #: names actually present in the plan's storage map)
        self.names = tuple(sorted(
            {plan.cfg.name_at(int(v)) for v in plan.store_levels.ravel()}))
        self.quants = tuple(_needs_quant(nm, plan.cfg) for nm in self.names)

    # -- per-shard storage map --------------------------------------------
    def row_tiles(self, s: int) -> range:
        return range(s * self.tps, (s + 1) * self.tps)

    def store_codes(self, j: int) -> np.ndarray:
        """(ntiles, tps) int32 table: ``codes[i, c]`` indexes
        :attr:`names` with the storage dtype of tile ``(i, j*tps + c)``.
        All shards share the table; shard ``s`` reads rows
        ``s*tps .. (s+1)*tps`` (a traced index under shard_map)."""
        cols = self.plan.store_levels[:, j * self.tps:(j + 1) * self.tps]
        lut = {lv: self.names.index(self.cfg.name_at(int(lv)))
               for lv in np.unique(cols)}
        return np.vectorize(lut.__getitem__, otypes=[np.int32])(cols)

    # -- local engine view -------------------------------------------------
    def diag_plan(self, j: int) -> PrecisionPlan:
        return self.plan.subplan(j * self.tps, (j + 1) * self.tps)

    # -- collective precision ----------------------------------------------
    def comm_level(self, j: int) -> int:
        """Coarsest compute level among trailing consumers of panel
        ``j``'s gathered column (lower-triangle pairs strictly below the
        panel). The last panel has no consumers: highest level."""
        lo = (j + 1) * self.tps
        T = self.plan.ntiles
        if lo >= T:
            return int(self.plan.levels.max())
        sub = self.plan.levels[lo:, lo:]
        return int(sub[np.tril_indices(sub.shape[0])].min())

    def comm_name(self, j: int) -> str:
        return self.cfg.name_at(self.comm_level(j))

    def comm_quant(self, j: int) -> bool:
        return _needs_quant(self.comm_name(j), self.cfg)

    def comm_table(self) -> tuple:
        """Static per-panel collective schedule the auditor reconciles
        against traced/compiled collectives: ``(panel, name, quant,
        wire)`` rows, ``wire`` the HLO dtype the gather moves in (16-bit
        floats bitcast to u16, int8 as s8; see ``_gather_panel``)."""
        return tuple(
            {"panel": j, "name": self.comm_name(j),
             "quant": self.comm_quant(j),
             "wire": WIRE_DTYPE[self.comm_name(j)]}
            for j in range(self.nshards))

    def describe(self) -> str:
        """Per-panel collective schedule (docs/ARCHITECTURE.md)."""
        lines = [f"ShardedPlan(nshards={self.nshards}, tps={self.tps}, "
                 f"w={self.panel_width}, ladder={self.cfg.describe()})"]
        for j in range(self.nshards):
            lines.append(f"  panel {j}: comm={self.comm_name(j)}"
                         f"{' (quantized)' if self.comm_quant(j) else ''}")
        return "\n".join(lines)

    def __repr__(self):
        return (f"ShardedPlan(n={self.plan.n}, nshards={self.nshards}, "
                f"ladder={self.cfg.describe()})")


def shard(plan: PrecisionPlan, nshards: int) -> ShardedPlan:
    """Block-row partition view of ``plan`` for an ``nshards`` mesh axis."""
    return ShardedPlan(plan, nshards)


@functools.lru_cache(maxsize=256)
def build_plan(n: int, cfg: PrecisionConfig) -> PrecisionPlan:
    """Cached plan construction (pure geometry — no array ops)."""
    return PrecisionPlan(n, cfg)
