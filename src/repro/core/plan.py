"""Static per-tile precision plan (the flat answer to the recursion).

The tree solver (:mod:`repro.core.tree`) assigns precision implicitly:
every recursion node computes its exposed GEMMs in ``levels[min(level,
-1)]`` and each leaf rounds its tile at the level the recursion happens
to reach. That assignment is a pure function of the *geometry* — matrix
size, leaf size, bisection rule — so it can be computed once, with no
array ops, as a per-tile table. This module walks the same recursion on
index ranges only and emits, for every ``leaf x leaf`` tile ``(i, j)``:

* ``level``    — the recursion level of the potrf node whose split
  separates ``i`` from ``j`` (for diagonal tiles: the depth of the path
  down to the singleton leaf). This is the level of every GEMM the tree
  exposes on the tile, i.e. its *compute* precision — the paper's
  "precision rises toward the diagonal" map.
* ``store_level`` — the (deeper, >= ``level``) recursion level at which
  the tree's TRSM leaf finally rounds the tile for storage.
* ``quantize`` — whether the paper's per-block quantization applies at
  the tile's compute level.

:func:`build_plan` is cached per ``(n, cfg)``; the flat blocked executor
(:mod:`repro.core.blocked`) looks tiles up here instead of re-deriving
precision by recursing, and :meth:`PrecisionPlan.describe` renders the
map for humans (README "Execution engines").
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core.precision import DTYPES, NARROW, PrecisionConfig


@dataclasses.dataclass(frozen=True)
class TileInfo:
    """Static precision assignment of one leaf tile."""

    level: int          # compute level (GEMM precision of the tile)
    name: str           # dtype name at the compute level
    store_level: int    # level whose dtype the tree stores the tile in
    store_name: str     # dtype name at the storage level
    quantize: bool      # per-block quantization applies at compute level

    @property
    def dtype(self):
        return DTYPES[self.name]

    @property
    def store_dtype(self):
        return DTYPES[self.store_name]


def _needs_quant(name: str, cfg: PrecisionConfig) -> bool:
    """Mirror of ``tree._round_to`` / ``cfg.needs_quant`` gating."""
    if name == "int8":
        return True
    return cfg.quantize and name in NARROW


def _split_tiles(nt: int) -> int:
    """cfg.split in tile units: leaf-aligned bisection point."""
    return max(1, nt // 2)


class PrecisionPlan:
    """Per-tile precision table for an ``n x n`` factorization.

    ``levels``/``store_levels`` are symmetric ``(T, T)`` int arrays
    (``T = n // leaf``); only the lower triangle is meaningful to the
    executor but the mirror keeps lookups order-free.
    """

    def __init__(self, n: int, cfg: PrecisionConfig):
        assert n % cfg.leaf == 0 and n > 0, (n, cfg.leaf)
        self.n = n
        self.cfg = cfg
        self.leaf = cfg.leaf
        self.ntiles = n // cfg.leaf
        T = self.ntiles
        comp = np.zeros((T, T), np.int32)
        store = np.zeros((T, T), np.int32)
        self._walk_potrf(comp, store, 0, T, 0)
        # mirror so (i, j) and (j, i) agree
        il = np.tril_indices(T, -1)
        comp[il[1], il[0]] = comp[il]
        store[il[1], il[0]] = store[il]
        self.levels = comp
        self.store_levels = store

    # -- construction (mirrors tree.py's recursion on index ranges) --------
    def _walk_potrf(self, comp, store, lo, hi, level):
        if hi - lo == 1:
            comp[lo, lo] = store[lo, lo] = level
            return
        mid = lo + _split_tiles(hi - lo)
        self._walk_potrf(comp, store, lo, mid, level + 1)
        # A21 block: every exposed GEMM runs at this node's level ...
        comp[mid:hi, lo:mid] = level
        # ... while the TRSM leaf that finally stores each column sits
        # deeper, at level + (column bisection depth):
        self._walk_trsm(store, mid, hi, lo, mid, level)
        self._walk_potrf(comp, store, mid, hi, level + 1)

    def _walk_trsm(self, store, rlo, rhi, clo, chi, level):
        if chi - clo == 1:
            store[rlo:rhi, clo] = level
            return
        cmid = clo + _split_tiles(chi - clo)
        self._walk_trsm(store, rlo, rhi, clo, cmid, level + 1)
        self._walk_trsm(store, rlo, rhi, cmid, chi, level + 1)

    # -- lookups -----------------------------------------------------------
    def level(self, i: int, j: int) -> int:
        return int(self.levels[i, j])

    def name(self, i: int, j: int) -> str:
        return self.cfg.name_at(self.level(i, j))

    def store_name(self, i: int, j: int) -> str:
        return self.cfg.name_at(int(self.store_levels[i, j]))

    def quant(self, i: int, j: int) -> bool:
        return _needs_quant(self.name(i, j), self.cfg)

    def tile(self, i: int, j: int) -> TileInfo:
        lv, sv = self.level(i, j), int(self.store_levels[i, j])
        name = self.cfg.name_at(lv)
        return TileInfo(level=lv, name=name, store_level=sv,
                        store_name=self.cfg.name_at(sv),
                        quantize=_needs_quant(name, self.cfg))

    def panel_meta(self, p: int) -> "PanelMeta":
        """Static metadata for the fused panel update at panel ``p``:
        storage names/quant flags for the trailing row tiles of column
        ``p`` and compute names/quant flags for every trailing pair."""
        cfg = self.cfg
        rows = range(p + 1, self.ntiles)
        store_names = tuple(self.store_name(i, p) for i in rows)
        store_quants = tuple(_needs_quant(nm, cfg) for nm in store_names)
        pair_names = tuple(tuple(self.name(i, j) for j in rows)
                           for i in rows)
        pair_quants = tuple(tuple(_needs_quant(nm, cfg) for nm in row)
                            for row in pair_names)
        return PanelMeta(store_names, store_quants, pair_names, pair_quants)

    # -- census hooks ------------------------------------------------------
    def level_counts(self) -> dict:
        """Lower-triangle tile count per compute dtype name."""
        counts: dict[str, int] = {}
        for i in range(self.ntiles):
            for j in range(i + 1):
                nm = self.name(i, j)
                counts[nm] = counts.get(nm, 0) + 1
        return counts

    def lowp_tile_fraction(self, names=("f16", "bf16", "int8")) -> float:
        counts = self.level_counts()
        total = sum(counts.values())
        low = sum(v for k, v in counts.items() if k in names)
        return low / total if total else 0.0

    def describe(self) -> str:
        """Human-readable tile map + census (README example)."""
        short = {"int8": "i8 ", "f16": "h16", "bf16": "b16", "f32": "f32",
                 "f64": "f64"}
        lines = [f"PrecisionPlan(n={self.n}, leaf={self.leaf}, "
                 f"tiles={self.ntiles}x{self.ntiles}, "
                 f"ladder={self.cfg.describe()})"]
        counts = self.level_counts()
        lines.append("  tiles: " + "  ".join(
            f"{k}={v}" for k, v in sorted(counts.items())))
        lines.append(f"  low-precision tile fraction: "
                     f"{self.lowp_tile_fraction():.2f}")
        for i in range(self.ntiles):
            row = " ".join(short.get(self.name(i, j), self.name(i, j))
                           for j in range(i + 1))
            lines.append("  " + row)
        return "\n".join(lines)

    def __repr__(self):
        return (f"PrecisionPlan(n={self.n}, leaf={self.leaf}, "
                f"ladder={self.cfg.describe()})")


@dataclasses.dataclass(frozen=True)
class PanelMeta:
    """Hashable (jit-static) per-panel metadata for the panel kernel."""

    store_names: tuple          # per trailing row tile of the panel
    store_quants: tuple
    pair_names: tuple           # [i][j] compute name of trailing pair
    pair_quants: tuple


@functools.lru_cache(maxsize=256)
def build_plan(n: int, cfg: PrecisionConfig) -> PrecisionPlan:
    """Cached plan construction (pure geometry — no array ops)."""
    return PrecisionPlan(n, cfg)
