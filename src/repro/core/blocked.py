"""Flat blocked mixed-precision Cholesky executor (copy-free tree).

The tree recursion (:mod:`repro.core.tree`) pays for its precision
assignment with ``jnp.concatenate`` reassembly of the full matrix at
every node — O(depth) whole-matrix copies and a dispatch DAG XLA cannot
fuse across. This module executes the *same* precision assignment as a
flat right-looking schedule over leaf panels of a single buffer:

    for each leaf panel p:
        L[p,p]   <- potrf leaf at the plan's diagonal level
        L[:, p]  <- fused panel update (kernels/panel.py): the TRSM
                    ``L21 = A21 @ L11^-T`` and the trailing SYRK
                    ``A22 -= L21 @ L21^T`` in one gridded kernel, with
                    every tile rounded/quantized once per use at the
                    precision :mod:`repro.core.plan` assigns it

No recursion and no per-node reassembly: the trailing matrix is carried
as a shrinking working set, every finished block column is emitted
exactly once, and the output is assembled in a single O(n^2) pass —
versus the tree's O(depth) whole-matrix concatenate chains.

Numerics vs the tree (the reference oracle): identical precision
assignment per tile — compute level = the potrf-split separation level,
storage level = the TRSM-leaf level, quantization per
``cfg.needs_quant``, and the trailing matrix stored at its tiles'
precision between updates (paper Fig. 3) — but the flat schedule rounds
trailing partial sums once per panel where the tree rounds once per
recursion node, so the blocked factor equals the tree factor up to the
ladder's own unit roundoff (and bit-identically for single-tile
problems, where both engines reduce to the same leaf call). The
equivalence suite (tests/test_blocked.py) pins this per PAPER_CONFIGS
entry. Triangular solves are O(n^2) against the O(n^3) factorization
and run in the ladder's high precision over the stored (rounded) factor.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.plan import build_plan
from repro.core.precision import PrecisionConfig
from repro.core.quantize import storage_round
from repro.core.tree import _sym_from_lower
from repro.kernels import ops


def _round(x, name: str, cfg: PrecisionConfig):
    """Storage rounding at ``name`` (no-op when the config disables it)."""
    if not cfg.storage_rounding:
        return x
    return storage_round(x, name, cfg.quantize)


def blocked_potrf(a, cfg: PrecisionConfig, *, plan=None):
    """Lower Cholesky factor of SPD ``a`` via the flat tile schedule.

    Reads the lower triangle only; returns L with zeroed upper triangle.
    ``a.shape[-1]`` must be a multiple of ``cfg.leaf`` (use
    :func:`repro.core.tree.pad_spd` otherwise — :func:`repro.core.solve.
    cholesky` does). Numerically equivalent to :func:`tree_potrf`; see
    the module docstring for the exact contract.

    ``plan`` overrides the per-tile precision table (default: the plan
    of ``a``'s own geometry). The distributed solver passes a
    :meth:`~repro.core.plan.PrecisionPlan.subplan` view here so its
    redundant diagonal-block factorizations compute every tile at the
    precision the GLOBAL plan assigns it.
    """
    a = jnp.asarray(a)
    n = a.shape[-1]
    assert a.shape == (n, n), a.shape
    assert n % cfg.leaf == 0, (n, cfg.leaf)
    if plan is None:
        plan = build_plan(n, cfg)
    assert plan.ntiles == n // cfg.leaf, (plan.ntiles, n, cfg.leaf)
    b, T, high = cfg.leaf, plan.ntiles, cfg.high_dtype
    # The trailing matrix is carried as a shrinking working set and each
    # finished block column is emitted exactly once — O(n^2) assembly
    # total, where the tree re-concatenates the full matrix at every
    # recursion node. (On the Pallas path the fused kernel additionally
    # keeps the trailing update tile-resident in VMEM per panel.)
    trail = a
    cols = []
    for p in range(T):
        name_p = plan.name(p, p)
        diag = _round(_sym_from_lower(trail[:b, :b]), name_p, cfg)
        lpp = ops.potrf(diag.astype(high), impl=cfg.kernel_impl)
        lpp = _round(lpp.astype(a.dtype), name_p, cfg)
        if p == T - 1:
            col = lpp
        else:
            linv = ops.tri_inv(lpp.astype(high), impl=cfg.kernel_impl)
            meta = plan.panel_meta(p)
            l21, trail = ops.panel_update(
                linv.astype(a.dtype), trail[b:, :b], trail[b:, b:],
                store_names=meta.store_names,
                store_quants=meta.store_quants,
                pair_names=meta.pair_names, pair_quants=meta.pair_quants,
                rounding=cfg.storage_rounding, impl=cfg.kernel_impl)
            col = jnp.concatenate([lpp, l21], axis=0)
        if p:
            col = jnp.concatenate([jnp.zeros((p * b, b), a.dtype), col],
                                  axis=0)
        cols.append(col)
    return cols[0] if T == 1 else jnp.concatenate(cols, axis=1)


def diag_tri_inv(l, cfg: PrecisionConfig):
    """Stacked inverses of the factor's diagonal leaf tiles, shape
    ``(T, leaf, leaf)``. Computed once per factor and reused by both
    triangular solves of every subsequent :func:`blocked_trsm_left`
    call — the serve engine caches this next to the factor, K-FAC-style
    repeated solves never re-invert a diagonal tile."""
    n = l.shape[-1]
    b = cfg.leaf
    assert n % b == 0, (n, b)
    high = cfg.high_dtype
    return jnp.stack([
        ops.tri_inv(l[i * b:(i + 1) * b, i * b:(i + 1) * b].astype(high),
                    impl=cfg.kernel_impl)
        for i in range(n // b)])


def blocked_trsm_left(bmat, l, cfg: PrecisionConfig, *, trans: bool,
                      linvs=None):
    """Flat left triangular solve against a blocked factor.

    trans=False : X = L^{-1} B   (forward substitution, one GEMM/panel)
    trans=True  : X = L^{-T} B   (back substitution, reversed order)

    ``bmat``: (n, k); ``l``: (n, n) lower-triangular with n a multiple of
    ``cfg.leaf``. ``linvs`` takes the precomputed :func:`diag_tri_inv`
    stack (the factor-cache hot path). The solve runs in the ladder's
    high precision — it is O(n^2) next to the O(n^3) factorization, so
    narrowing it would buy nothing and cost digits.
    """
    bmat = jnp.asarray(bmat)
    n, _ = bmat.shape
    assert l.shape == (n, n), (bmat.shape, l.shape)
    b = cfg.leaf
    assert n % b == 0, (n, b)
    T = n // b
    if linvs is None:
        linvs = diag_tri_inv(l, cfg)
    high = cfg.high_dtype
    x = bmat.astype(high)
    impl = cfg.kernel_impl
    if not trans:
        for p in range(T):
            r0, r1 = p * b, (p + 1) * b
            xp = ops.qgemm(linvs[p], x[r0:r1], out_dtype=high, impl=impl)
            x = x.at[r0:r1].set(xp)
            if r1 < n:
                x = x.at[r1:].set(ops.qgemm(
                    l[r1:, r0:r1].astype(high), xp, scale=-1.0,
                    c=x[r1:], beta=1.0, out_dtype=high, impl=impl))
    else:
        for p in reversed(range(T)):
            r0, r1 = p * b, (p + 1) * b
            xp = ops.qgemm(linvs[p].T, x[r0:r1], out_dtype=high, impl=impl)
            x = x.at[r0:r1].set(xp)
            if r0 > 0:
                x = x.at[:r0].set(ops.qgemm(
                    l[r0:r1, :r0].T.astype(high), xp, scale=-1.0,
                    c=x[:r0], beta=1.0, out_dtype=high, impl=impl))
    return x.astype(bmat.dtype)
