"""repro.core — the paper's contribution as a composable JAX module.

Public API:
  PrecisionConfig, PAPER_CONFIGS      — layered precision ladders
  cholesky, cholesky_solve, logdet    — mixed-precision SPD solver
  tree_potrf, tree_trsm, tree_syrk    — the nested recursive routines
  quant_block / dequant               — per-block quantization
  refine_solve, RefineConfig, ...     — mixed-precision iterative refinement
  census_*                            — structural FLOP/byte census
  distributed (module)                — shard_map block-panel Cholesky
"""
from repro.core.blocked import (blocked_potrf, blocked_trsm_left,
                                diag_tri_inv)
from repro.core.plan import (PrecisionPlan, ShardedPlan, TileInfo,
                             build_plan, shard)
from repro.core.precision import (DTYPES, PAPER_CONFIGS, PEAK_FLOPS, RMAX,
                                  PrecisionConfig)
from repro.core.quantize import (dequant, dequant_int8, quant_block,
                                 quant_int8, storage_round)
from repro.core.refine import (RefineConfig, RefineResult, gmres_refine,
                               iterative_refine, refine_operator,
                               refine_steps, scaled_solve)
from repro.core.solve import (cholesky, cholesky_jit, cholesky_padded,
                              cholesky_solve, cholesky_solve_jit, logdet,
                              refine_solve, solve_factored)
from repro.core.tree import (pad_factor, pad_spd, tree_potrf, tree_trsm,
                             tree_trsm_left, tree_syrk)
from repro.core.census import Census, census_potrf, census_syrk, census_trsm
from repro.core.treematrix import (TreeSPD, storage_ratio,
                                   tree_potrf_packed)

__all__ = [
    "DTYPES", "PAPER_CONFIGS", "PEAK_FLOPS", "RMAX", "PrecisionConfig",
    "PrecisionPlan", "ShardedPlan", "TileInfo", "build_plan", "shard",
    "blocked_potrf", "blocked_trsm_left", "diag_tri_inv",
    "dequant", "dequant_int8", "quant_block", "quant_int8",
    "storage_round",
    "RefineConfig", "RefineResult", "gmres_refine", "iterative_refine",
    "refine_operator", "refine_steps", "scaled_solve",
    "cholesky", "cholesky_jit", "cholesky_padded", "cholesky_solve",
    "cholesky_solve_jit", "logdet", "refine_solve", "solve_factored",
    "pad_factor", "pad_spd", "tree_potrf", "tree_trsm", "tree_trsm_left",
    "tree_syrk",
    "Census", "census_potrf", "census_syrk", "census_trsm",
    "TreeSPD", "storage_ratio", "tree_potrf_packed",
]
