"""Multi-chip distributed Cholesky via shard_map.

(Architecture notes: docs/ARCHITECTURE.md, "Distributed solver".)

1-D block-row layout: device i of the ``axis`` mesh axis owns rows
[i*w, (i+1)*w) of the global (n, n) SPD matrix, w = n/P. The factorization
is a right-looking panel sweep whose *step loop unrolls at trace time*
(P is static), so every trailing update has exact static shapes — no
masked FLOP waste.

Per panel j:
  1. broadcast (or all-gather) the (w, w) diagonal block   (comm: w^2|n*w)
  2. every device factorizes the diagonal block redundantly (tiny vs the
     panel) and TRSMs its own row block                     (compute: w^3)
  3. all-gather the solved panel                            (comm: n*w)
  4. local trailing GEMM update of its rows (qgemm, mixed precision)

The local POTRF/TRSM are the same precision-planned engines as the
single-device path (``cfg.engine`` selects them):

* ``"blocked"`` (default) — :func:`repro.core.blocked.blocked_potrf` /
  :func:`~repro.core.blocked.blocked_trsm_left`, driven by the global
  :class:`~repro.core.plan.PrecisionPlan` partitioned by block row
  (:func:`repro.core.plan.shard`). The diagonal factorization runs on a
  :meth:`~repro.core.plan.PrecisionPlan.subplan` view so every tile
  keeps its GLOBAL precision, each shard storage-rounds its block-row
  slice of the solved panel per the plan, and — for w > leaf — the
  per-panel fused panel kernel (:mod:`repro.kernels.panel`) dispatches
  locally inside the diagonal factorization.
* ``"tree"`` — the paper's recursive routines (the pre-plan schedule,
  kept as the distributed reference oracle and raced by
  ``benchmarks/bench_dist.py``).

Collectives are quantized by default (``compress_comm=True``): the
solved panel travels at the precision the sharded plan assigns the
collective — the coarsest level any trailing consumer computes at
(:meth:`~repro.core.plan.ShardedPlan.comm_name`). Early panels move in
the ladder's low precision (halving the dominant n*w term, per-shard
scales riding along as (P,) f32); panels near the diagonal, whose
consumers all compute at fine levels, are gathered losslessly. The tree
engine predates the plan and always compresses at level 0. Collective
cost 2*n*w per step is the open perf item (docs/ARCHITECTURE.md,
"Performance notes" C3: replace gather-1 with a (w, w) ppermute
broadcast).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.blocked import blocked_potrf, blocked_trsm_left, diag_tri_inv
from repro.core.plan import build_plan, shard
from repro.core.precision import PrecisionConfig
from repro.core.quantize import quant_block, storage_round
from repro.core.tree import tree_potrf, tree_trsm, tree_trsm_left
from repro.kernels import ops


def _gather_panel(li, name: str, quant: bool, axis: str, compress: bool):
    """All-gather the solved (w, w) panel block at precision ``name``.

    Returns ``(liq, s1, gathered)``: the local block quantized to the
    collective's dtype, its scale, and the (P, w, w) gather in that
    dtype. ``compress=False`` or a wide ``name`` moves raw f32 — the
    quantization then happens after the gather, exactly as before.
    """
    if not compress or name in ("f32", "f64"):
        gath = jax.lax.all_gather(li, axis)
        return None, None, gath
    liq, s1 = quant_block(li, name, quant)
    if liq.dtype == jnp.int8:
        gath = jax.lax.all_gather(liq, axis)         # int8 wire format
    else:
        # bitcast to u16 so XLA cannot commute the 16-bit -> f32 convert
        # ahead of the collective (it otherwise gathers at f32, doubling
        # the bytes — measured in benchmarks/bench_dist.py)
        bits = jax.lax.bitcast_convert_type(liq, jnp.uint16)
        gath = jax.lax.bitcast_convert_type(jax.lax.all_gather(bits, axis),
                                            liq.dtype)
    return liq, s1, gath


def _round_panel_rows(li, my, codes, names, quants, leaf: int):
    """Round each (leaf, leaf) tile of a shard's (w, w) panel block onto
    the storage grid its plan slice assigns it.

    ``codes`` is the ShardedPlan's (T, tps) int32 store-code table
    (shared by all shards — SPMD traces once); ``my`` is the traced
    shard id, so shard s reads rows ``s*tps + r``. Mirrors the panel
    kernel's static-variants + traced-select idiom at the jnp level.
    """
    tps = codes.shape[1]
    out = li
    for r in range(tps):
        for c in range(tps):
            tile = li[r * leaf:(r + 1) * leaf, c * leaf:(c + 1) * leaf]
            code = codes[my * tps + r, c]
            t = storage_round(tile, names[0], quants[0])
            for k in range(1, len(names)):
                t = jnp.where(code == k,
                              storage_round(tile, names[k], quants[k]), t)
            out = out.at[r * leaf:(r + 1) * leaf,
                         c * leaf:(c + 1) * leaf].set(t)
    return out


def _local_potrf_blocked(a_local, *, axis: str, nshards: int,
                         cfg: PrecisionConfig, broadcast_diag_only: bool,
                         compress_comm: bool):
    """Plan-driven local engine: blocked POTRF/TRSM + planned collectives."""
    w, n = a_local.shape
    my = jax.lax.axis_index(axis)
    sp = shard(build_plan(n, cfg), nshards)
    for j in range(nshards):
        colpanel = a_local[:, j * w:(j + 1) * w]                 # (w, w)
        if broadcast_diag_only:
            # Optimized collective schedule (perf note C1): only the
            # owner's (w, w) diagonal block moves (psum of a masked
            # block), saving the first n*w all-gather.
            mine = jnp.where(my == j, colpanel, jnp.zeros_like(colpanel))
            diag = jax.lax.psum(mine, axis)
        else:
            diag = jax.lax.all_gather(colpanel, axis)[j]
        # redundant diagonal factorization at the GLOBAL plan's tile
        # precisions; w > leaf dispatches the fused panel kernel inside
        ld = blocked_potrf(diag, cfg, plan=sp.diag_plan(j))
        linvs = diag_tri_inv(ld, cfg)
        # own row block: li = colpanel @ ld^{-T}  via  (ld^{-1} colpanel^T)^T
        li = blocked_trsm_left(colpanel.T, ld, cfg, trans=False,
                               linvs=linvs).T
        if cfg.storage_rounding:
            # each shard rounds ITS block-row slice of the solved panel
            # onto the plan's storage grids (the single-device engine's
            # TRSM-leaf rounding, partitioned by block row)
            codes = jnp.asarray(sp.store_codes(j))
            li = _round_panel_rows(li, my, codes, sp.names, sp.quants,
                                   cfg.leaf)
        li = jnp.where(my == j, ld, li)     # owner keeps the exact factor
        if j < nshards - 1:
            # collective + trailing update at the sharded plan's comm
            # precision: the coarsest level any trailing consumer runs at
            name, q = sp.comm_name(j), sp.comm_quant(j)
            trail0 = (j + 1) * w
            liq, s1, gath = _gather_panel(li, name, q, axis, compress_comm)
            if liq is None:                  # wide (or uncompressed) wire
                lt = gath[j + 1:].reshape(-1, w)
                liq, s1 = quant_block(li, name, q)
                ltq, s2 = quant_block(lt, name, q)
                a_local = a_local.at[:, trail0:].set(
                    ops.qgemm(liq, ltq, scale=-(s1 * s2),
                              c=a_local[:, trail0:], beta=1.0,
                              trans_b=True, out_dtype=a_local.dtype,
                              impl=cfg.kernel_impl))
            else:                            # quantized collective
                lt = gath[j + 1:].reshape(-1, w)
                upd = ops.qgemm(liq, lt, scale=s1, trans_b=True,
                                out_dtype=jnp.float32,
                                impl=cfg.kernel_impl)            # (w, m)
                if q:
                    # per-shard scales travel as (P,) f32 and rescale the
                    # GEMM output column blocks
                    scales = jax.lax.all_gather(s1, axis)        # (P,)
                    upd = upd * jnp.repeat(scales[j + 1:], w)[None, :]
                a_local = a_local.at[:, trail0:].add(
                    -upd.astype(a_local.dtype))
        a_local = a_local.at[:, j * w:(j + 1) * w].set(li)
    # zero the (junk-filled) upper triangle of my rows
    gr = jnp.arange(w)[:, None] + my * w
    keep = jnp.arange(n)[None, :] <= gr
    return jnp.where(keep, a_local, 0.0)


def _local_potrf_tree(a_local, *, axis: str, nshards: int,
                      cfg: PrecisionConfig, broadcast_diag_only: bool,
                      compress_comm: bool):
    """Legacy local engine: the paper's recursive routines, level-0 comm.

    Kept as the distributed reference oracle (``cfg.engine == "tree"``)
    and the baseline ``benchmarks/bench_dist.py`` races the planned
    blocked engine against.
    """
    w, n = a_local.shape
    my = jax.lax.axis_index(axis)
    for j in range(nshards):
        colpanel = a_local[:, j * w:(j + 1) * w]                 # (w, w)
        if broadcast_diag_only:
            mine = jnp.where(my == j, colpanel, jnp.zeros_like(colpanel))
            diag = jax.lax.psum(mine, axis)
        else:
            allpan = jax.lax.all_gather(colpanel, axis)          # (P, w, w)
            diag = allpan[j]
        ld = tree_potrf(diag, cfg)                               # redundant
        li = tree_trsm(colpanel, ld, cfg)
        li = jnp.where(my == j, ld, li)
        name = cfg.name_at(0)
        q = cfg.needs_quant(0)
        if compress_comm and j < nshards - 1:
            # the tree predates the plan: the trailing update always
            # consumes the gathered panel at level-0 precision, so the
            # collective always quantizes to level 0
            liq, s1, gath = _gather_panel(li, name, q, axis, True)
            lt = gath[j + 1:].reshape(-1, w)
            upd = ops.qgemm(liq, lt, scale=s1, trans_b=True,
                            out_dtype=jnp.float32,
                            impl=cfg.kernel_impl)                # (w, m)
            if q:
                scales = jax.lax.all_gather(s1, axis)            # (P,)
                upd = upd * jnp.repeat(scales[j + 1:], w)[None, :]
            a_local = a_local.at[:, (j + 1) * w:].add(
                -upd.astype(a_local.dtype))
        elif j < nshards - 1:
            solved = jax.lax.all_gather(li, axis)                # (P, w, w)
            lt = solved[j + 1:].reshape(-1, w)                   # f32 rows
            liq, s1 = quant_block(li, name, q)
            ltq, s2 = quant_block(lt, name, q)
            a_local = a_local.at[:, (j + 1) * w:].set(
                ops.qgemm(liq, ltq, scale=-(s1 * s2),
                          c=a_local[:, (j + 1) * w:], beta=1.0,
                          trans_b=True, out_dtype=a_local.dtype,
                          impl=cfg.kernel_impl))
        a_local = a_local.at[:, j * w:(j + 1) * w].set(li)
    # zero the (junk-filled) upper triangle of my rows
    gr = jnp.arange(w)[:, None] + my * w
    keep = jnp.arange(n)[None, :] <= gr
    return jnp.where(keep, a_local, 0.0)


def _autoresolve(cfg: PrecisionConfig, n: int, nshards: int):
    """Resolve ``engine="auto"`` via the tuning DB (no-op otherwise)."""
    if cfg.engine != "auto":
        return cfg
    from repro import tune  # local: tune is a consumer of this module
    return tune.resolve_cfg(cfg, n, nshards)


def dist_cholesky(a, mesh, cfg: PrecisionConfig | None = None,
                  axis: str = "model", *, broadcast_diag_only: bool = True,
                  compress_comm: bool | None = None):
    """Distributed lower Cholesky of a block-row-sharded SPD matrix.

    ``a``: global (n, n), n divisible by ``mesh.shape[axis] * cfg.leaf``.
    Returns L with the same sharding. ``cfg.engine`` selects the local
    engine (``"blocked"`` — plan-driven, the default — ``"tree"``, the
    recursive oracle, or ``"auto"`` to consult the tuning database for
    the measured winner at this ``(n, nshards)``; docs/TUNING.md).
    ``compress_comm`` gathers the solved panel in the precision the
    sharded plan assigns the collective; ``False`` forces full-precision
    gathers (the baseline ``benchmarks/bench_dist.py`` races) and the
    default ``None`` takes the tuning database's measured choice
    (falling back to compressed).
    """
    cfg = cfg or PrecisionConfig()
    nshards = mesh.shape[axis]
    n = a.shape[-1]
    assert n % nshards == 0 and (n // nshards) % cfg.leaf == 0, (
        f"n={n} must be divisible by shards*leaf={nshards}*{cfg.leaf}")
    cfg = _autoresolve(cfg, n, nshards)
    if compress_comm is None:
        from repro import tune
        compress_comm = tune.decide(
            n, tune.ladder_key(cfg), nshards).compress_comm
    local = (_local_potrf_tree if cfg.engine == "tree"
             else _local_potrf_blocked)
    fn = functools.partial(local, axis=axis, nshards=nshards, cfg=cfg,
                           broadcast_diag_only=broadcast_diag_only,
                           compress_comm=compress_comm)
    spec = P(axis, None)
    return shard_map(fn, mesh=mesh, in_specs=(spec,), out_specs=spec)(a)


def _local_solve(l_local, b_local, *, axis: str, nshards: int,
                 cfg: PrecisionConfig):
    """Forward then back substitution on block-row-sharded L and B.

    The per-shard diagonal solves run through the engine ``cfg.engine``
    selects — :func:`~repro.core.blocked.blocked_trsm_left` (flat GEMM
    substitution against cached leaf inverses) by default, the recursive
    :func:`~repro.core.tree.tree_trsm_left` for the tree oracle. Both
    run in the ladder's high precision: the solve is O(n^2) next to the
    O(n^3) factorization, so narrowing it would buy nothing.
    """
    w = l_local.shape[0]
    my = jax.lax.axis_index(axis)
    nrhs = b_local.shape[1]

    def trsm_left(bm, lm, trans):
        if cfg.engine == "tree":
            return tree_trsm_left(bm, lm, cfg, trans=trans)
        return blocked_trsm_left(bm, lm, cfg, trans=trans)

    # forward: y_j = L_jj^{-1} (b_j - sum_{k<j} L_jk y_k)
    y = jnp.zeros_like(b_local)
    for j in range(nshards):
        acc = b_local
        if j > 0:
            yg = jax.lax.all_gather(y, axis)                     # (P, w, r)
            past = yg[:j].reshape(-1, nrhs)                      # (j*w, r)
            lpast = l_local[:, :j * w]
            acc = b_local - ops.qgemm(
                lpast.astype(cfg.high_dtype), past.astype(cfg.high_dtype),
                out_dtype=b_local.dtype, impl=cfg.kernel_impl)
        diag_mine = jnp.where(
            my == j, l_local[:, j * w:(j + 1) * w],
            jnp.zeros((w, w), l_local.dtype))
        diag = jax.lax.psum(diag_mine, axis)
        yj = trsm_left(acc, diag, False)
        y = jnp.where(my == j, yj, y)
    # backward: x_j = L_jj^{-T} (y_j - sum_{k>j} L_kj^T x_k)
    x = jnp.zeros_like(y)
    for j in reversed(range(nshards)):
        acc = y
        if j < nshards - 1:
            xg = jax.lax.all_gather(x, axis)                     # (P, w, r)
            future = xg[j + 1:].reshape(-1, nrhs)                # ((P-j-1)w, r)
            # need L[rows>j*w.., cols j]^T  = (column panel j below diag)^T;
            # column panel j rows are spread across devices k > j: gather
            # each device's (w, w) block of column panel j.
            myblk = l_local[:, j * w:(j + 1) * w]                # (w, w)
            blks = jax.lax.all_gather(myblk, axis)               # (P, w, w)
            below = blks[j + 1:].reshape(-1, w)                  # ((P-j-1)w, w)
            acc = y - ops.qgemm(
                below.T.astype(cfg.high_dtype), future.astype(cfg.high_dtype),
                out_dtype=y.dtype, impl=cfg.kernel_impl)
        diag_mine = jnp.where(
            my == j, l_local[:, j * w:(j + 1) * w],
            jnp.zeros((w, w), l_local.dtype))
        diag = jax.lax.psum(diag_mine, axis)
        xj = trsm_left(acc, diag, True)
        x = jnp.where(my == j, xj, x)
    return x


def dist_cholesky_solve(a, b, mesh, cfg: PrecisionConfig | None = None,
                        axis: str = "model", *, l=None):
    """Solve A x = b with A (and b) block-row-sharded over ``axis``."""
    cfg = cfg or PrecisionConfig()
    nshards = mesh.shape[axis]
    cfg = _autoresolve(cfg, a.shape[-1] if a is not None else b.shape[0],
                       nshards)
    if l is None:
        l = dist_cholesky(a, mesh, cfg, axis)
    vec = b.ndim == 1
    if vec:
        b = b[:, None]
    fn = functools.partial(_local_solve, axis=axis, nshards=nshards, cfg=cfg)
    x = shard_map(fn, mesh=mesh,
                      in_specs=(P(axis, None), P(axis, None)),
                      out_specs=P(axis, None))(l, b)
    return x[:, 0] if vec else x
