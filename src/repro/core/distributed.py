"""Multi-chip distributed Cholesky via shard_map (DESIGN.md §4.4).

1-D block-row layout: device i of the ``axis`` mesh axis owns rows
[i*w, (i+1)*w) of the global (n, n) SPD matrix, w = n/P. The factorization
is a right-looking panel sweep whose *step loop unrolls at trace time*
(P is static), so every trailing update has exact static shapes — no
masked FLOP waste.

Per panel j:
  1. all-gather the raw column panel            (comm: n*w)
  2. every device factorizes the (w, w) diagonal block redundantly with
     the paper's tree-POTRF (tiny vs the panel) and tree-TRSMs its own
     row block                                   (compute: w^3/3 + w^3)
  3. all-gather the solved panel                 (comm: n*w)
  4. local trailing GEMM update of its rows (qgemm, mixed precision)

The local POTRF/TRSM/GEMM are exactly the paper's recursive mixed-
precision routines, so the precision ladder applies unchanged on every
shard. Collective cost 2*n*w per step is the §Perf hillclimb target
(EXPERIMENTS.md: replace gather-1 with a (w,w) ppermute broadcast).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.precision import PrecisionConfig
from repro.core.quantize import quant_block
from repro.core.tree import tree_potrf, tree_trsm, tree_trsm_left
from repro.kernels import ops


def _local_potrf(a_local, *, axis: str, nshards: int, cfg: PrecisionConfig,
                 broadcast_diag_only: bool, compress_comm: bool):
    w, n = a_local.shape
    my = jax.lax.axis_index(axis)
    for j in range(nshards):
        colpanel = a_local[:, j * w:(j + 1) * w]                 # (w, w)
        if broadcast_diag_only:
            # Optimized collective schedule (§Perf C1): only the owner's
            # (w, w) diagonal block is broadcast (psum of a masked block),
            # saving the first n*w all-gather.
            mine = jnp.where(my == j, colpanel, jnp.zeros_like(colpanel))
            diag = jax.lax.psum(mine, axis)
        else:
            allpan = jax.lax.all_gather(colpanel, axis)          # (P, w, w)
            diag = allpan[j]
        ld = tree_potrf(diag, cfg)                               # redundant
        li = tree_trsm(colpanel, ld, cfg)
        li = jnp.where(my == j, ld, li)
        name = cfg.name_at(0)
        q = cfg.needs_quant(0)
        if compress_comm and j < nshards - 1:
            # §Perf C2: the trailing update consumes the gathered panel
            # at the level-0 precision anyway — so quantize BEFORE the
            # all-gather (the paper's per-block quantization applied to
            # the collective): halves the dominant n*w term at zero
            # extra rounding vs the in-compute quantization. Per-shard
            # scales travel as (P,) f32 and rescale the GEMM output
            # column blocks.
            liq, s1 = quant_block(li, name, q)
            # bitcast to u16 so XLA cannot commute the bf16->f32 convert
            # ahead of the collective (it otherwise gathers at f32,
            # doubling the bytes — measured in §Perf C2)
            bits = jax.lax.bitcast_convert_type(liq, jnp.uint16)
            gbits = jax.lax.all_gather(bits, axis)               # lowp!
            gath = jax.lax.bitcast_convert_type(gbits, liq.dtype)
            lt = gath[j + 1:].reshape(-1, w)
            upd = ops.qgemm(liq, lt, scale=s1, trans_b=True,
                            out_dtype=jnp.float32,
                            impl=cfg.kernel_impl)                # (w, m)
            if q:
                scales = jax.lax.all_gather(s1, axis)            # (P,)
                upd = upd * jnp.repeat(scales[j + 1:], w)[None, :]
            a_local = a_local.at[:, (j + 1) * w:].add(
                -upd.astype(a_local.dtype))
        elif j < nshards - 1:
            solved = jax.lax.all_gather(li, axis)                # (P, w, w)
            lt = solved[j + 1:].reshape(-1, w)                   # f32 rows
            liq, s1 = quant_block(li, name, q)
            ltq, s2 = quant_block(lt, name, q)
            a_local = a_local.at[:, (j + 1) * w:].set(
                ops.qgemm(liq, ltq, scale=-(s1 * s2),
                          c=a_local[:, (j + 1) * w:], beta=1.0,
                          trans_b=True, out_dtype=a_local.dtype,
                          impl=cfg.kernel_impl))
        a_local = a_local.at[:, j * w:(j + 1) * w].set(li)
    # zero the (junk-filled) upper triangle of my rows
    gr = jnp.arange(w)[:, None] + my * w
    keep = jnp.arange(n)[None, :] <= gr
    return jnp.where(keep, a_local, 0.0)


def dist_cholesky(a, mesh, cfg: PrecisionConfig | None = None,
                  axis: str = "model", *, broadcast_diag_only: bool = True,
                  compress_comm: bool = False):
    """Distributed lower Cholesky of a block-row-sharded SPD matrix.

    ``a``: global (n, n), n divisible by ``mesh.shape[axis] * cfg.leaf``.
    Returns L with the same sharding. ``compress_comm`` gathers the
    solved panel in the level-0 low precision (§Perf C2).
    """
    cfg = cfg or PrecisionConfig()
    nshards = mesh.shape[axis]
    n = a.shape[-1]
    assert n % nshards == 0 and (n // nshards) % cfg.leaf == 0, (
        f"n={n} must be divisible by shards*leaf={nshards}*{cfg.leaf}")
    fn = functools.partial(_local_potrf, axis=axis, nshards=nshards, cfg=cfg,
                           broadcast_diag_only=broadcast_diag_only,
                           compress_comm=compress_comm)
    spec = P(axis, None)
    return shard_map(fn, mesh=mesh, in_specs=(spec,), out_specs=spec)(a)


def _local_solve(l_local, b_local, *, axis: str, nshards: int,
                 cfg: PrecisionConfig):
    """Forward then back substitution on block-row-sharded L and B."""
    w = l_local.shape[0]
    my = jax.lax.axis_index(axis)
    nrhs = b_local.shape[1]

    # forward: y_j = L_jj^{-1} (b_j - sum_{k<j} L_jk y_k)
    y = jnp.zeros_like(b_local)
    for j in range(nshards):
        acc = b_local
        if j > 0:
            yg = jax.lax.all_gather(y, axis)                     # (P, w, r)
            past = yg[:j].reshape(-1, nrhs)                      # (j*w, r)
            lpast = l_local[:, :j * w]
            acc = b_local - ops.qgemm(
                lpast.astype(cfg.high_dtype), past.astype(cfg.high_dtype),
                out_dtype=b_local.dtype, impl=cfg.kernel_impl)
        diag_mine = jnp.where(
            my == j, l_local[:, j * w:(j + 1) * w],
            jnp.zeros((w, w), l_local.dtype))
        diag = jax.lax.psum(diag_mine, axis)
        yj = tree_trsm_left(acc, diag, cfg, trans=False)
        y = jnp.where(my == j, yj, y)
    # backward: x_j = L_jj^{-T} (y_j - sum_{k>j} L_kj^T x_k)
    x = jnp.zeros_like(y)
    for j in reversed(range(nshards)):
        acc = y
        if j < nshards - 1:
            xg = jax.lax.all_gather(x, axis)                     # (P, w, r)
            future = xg[j + 1:].reshape(-1, nrhs)                # ((P-j-1)w, r)
            # need L[rows>j*w.., cols j]^T  = (column panel j below diag)^T;
            # column panel j rows are spread across devices k > j: gather
            # each device's (w, w) block of column panel j.
            myblk = l_local[:, j * w:(j + 1) * w]                # (w, w)
            blks = jax.lax.all_gather(myblk, axis)               # (P, w, w)
            below = blks[j + 1:].reshape(-1, w)                  # ((P-j-1)w, w)
            acc = y - ops.qgemm(
                below.T.astype(cfg.high_dtype), future.astype(cfg.high_dtype),
                out_dtype=y.dtype, impl=cfg.kernel_impl)
        diag_mine = jnp.where(
            my == j, l_local[:, j * w:(j + 1) * w],
            jnp.zeros((w, w), l_local.dtype))
        diag = jax.lax.psum(diag_mine, axis)
        xj = tree_trsm_left(acc, diag, cfg, trans=True)
        x = jnp.where(my == j, xj, x)
    return x


def dist_cholesky_solve(a, b, mesh, cfg: PrecisionConfig | None = None,
                        axis: str = "model", *, l=None):
    """Solve A x = b with A (and b) block-row-sharded over ``axis``."""
    cfg = cfg or PrecisionConfig()
    if l is None:
        l = dist_cholesky(a, mesh, cfg, axis)
    nshards = mesh.shape[axis]
    vec = b.ndim == 1
    if vec:
        b = b[:, None]
    fn = functools.partial(_local_solve, axis=axis, nshards=nshards, cfg=cfg)
    x = shard_map(fn, mesh=mesh,
                      in_specs=(P(axis, None), P(axis, None)),
                      out_specs=P(axis, None))(l, b)
    return x[:, 0] if vec else x
