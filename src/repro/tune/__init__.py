"""Measured-search autotuner + tuning database for the plan/engine knobs.

(docs/TUNING.md is the user-facing guide.)

Performance of the solver stack hinges on knobs that are size- and
backend-dependent: execution engine (the distributed benchmark measured
the blocked local engine LOSING to the tree at n=1024 and winning at
n=2048), leaf size, collective compression, the serving batch geometry.
This package replaces the hand-picked constants with measurement:

* :func:`autotune` (``python -m repro.tune``, or ``benchmarks/run.py
  --tune``) profiles candidate configurations and persists winners —
  including interpolated engine-crossover sizes — to a JSON database
  keyed by ``(backend, n, ladder, nshards)``.
* :func:`decide` resolves a key against the committed per-backend
  database (``repro/tune/data/<backend>.json``, override with
  ``REPRO_TUNING_DB``) with a deterministic nearest-key fallback chain
  ending at today's defaults.
* :func:`resolve_cfg` is the factor-time hook: a
  :class:`~repro.core.precision.PrecisionConfig` with ``engine="auto"``
  is resolved to the measured winner for its problem size before any
  schedule is built. ``dist_cholesky(compress_comm=None)`` and
  ``SolverEngine(dist_threshold=None)`` consult the same database.
"""
from __future__ import annotations

import dataclasses

from repro.tune.db import (DEFAULTS, TunedDecision, TuningDB,  # noqa: F401
                           clear_cache, decide, default_db_path,
                           get_default_db, ladder_key, load_db,
                           validate_db, verify_consultation)
from repro.tune.search import autotune, interp_crossover  # noqa: F401


def resolve_cfg(cfg, n: int, nshards: int = 1, *, db=None):
    """Resolve ``engine="auto"`` to the tuned engine for size ``n``.

    Any other engine value passes through untouched, so explicit
    ``engine="tree"``/``"blocked"`` configs keep meaning what they say.
    The leaf is never changed here — plan geometry is the caller's
    contract (factor caches and solves must agree on it); callers that
    want the tuned leaf read ``decide(...).leaf`` before building their
    config.
    """
    if cfg.engine != "auto":
        return cfg
    dec = decide(n, ladder_key(cfg), nshards, db=db)
    return dataclasses.replace(cfg, engine=dec.engine)
