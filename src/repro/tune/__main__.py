"""CLI for the autotuner: regenerate or verify a tuning database.

Regenerate the committed CPU database (what ``benchmarks/run.py --tune``
runs, with the forced 4-device mesh set up for you):

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m repro.tune --out src/repro/tune/data/cpu.json

CI's autotune-smoke job runs ``--smoke`` (tiny sizes) and then
``--verify`` on the emitted file, which checks the schema and that
lookups actually follow the measured engine crossover (tree below,
blocked above). Exit status is non-zero on any violation.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.tune",
                                 description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny candidate sizes (CI autotune-smoke job)")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="database path to write (default: the packaged "
                         "per-backend file under repro/tune/data/)")
    ap.add_argument("--backend", default=None,
                    help="backend key (default: jax.default_backend())")
    ap.add_argument("--ladders", default="bf16_f32",
                    help="comma-separated ladder keys to tune")
    ap.add_argument("--verify", default=None, metavar="PATH",
                    help="validate an existing database and check the "
                         "lookup follows its crossovers; no tuning run")
    args = ap.parse_args(argv)

    from repro.tune import db as tdb

    if args.verify:
        loaded = tdb.load_db(args.verify)
        if loaded is None:
            print(f"FAIL: could not load tuning DB at {args.verify}")
            return 1
        errs = tdb.verify_consultation(loaded)
        for e in errs:
            print(f"FAIL: {e}")
        print(f"verify {args.verify}: "
              f"{'FAIL' if errs else 'OK'} ({len(loaded.entries)} entries, "
              f"{len(loaded.crossovers)} crossovers)")
        return 1 if errs else 0

    from repro.tune.search import autotune
    print("name,us_per_call,derived")
    payload = autotune(args.backend, smoke=args.smoke,
                       ladders=tuple(args.ladders.split(",")))
    out = args.out or os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                   "data", f"{payload['backend']}.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w", encoding="utf-8") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# wrote {len(payload['entries'])} entries / "
          f"{len(payload['crossovers'])} crossovers to {out}",
          file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
