"""Tuning database: persisted winners of the measured knob search.

(How to re-tune and how CI consumes this: docs/TUNING.md.)

The autotuner (:mod:`repro.tune.search`) times candidate configurations
over the plan/engine knob space and persists the winners here as a plain
JSON payload keyed by ``(backend, n, ladder, nshards)``. At factor time
the solver consults the database through :func:`decide`, which resolves
a key to a :class:`TunedDecision` with a DETERMINISTIC relaxation order:

1. exact ``(backend, n, ladder, nshards)`` entry,
2. the measured engine **crossover** for ``(backend, ladder, nshards)``
   (the interpolated problem size where the blocked engine starts
   beating the tree engine), with the remaining knobs taken from the
   nearest-``n`` entry,
3. the nearest-``n`` entry for ``(backend, ladder, nshards)``
   (log-space distance, ties to the smaller ``n``),
4. the nearest entry for ``(backend, nshards)`` across ladders,
5. today's hand-picked defaults (:data:`DEFAULTS`) — the behaviour the
   repo had before the tuner existed.

A corrupt database, or a missing file the user explicitly pointed
``REPRO_TUNING_DB`` at, falls back to :data:`DEFAULTS` with a warning
(never an exception): tuning is a performance layer, not a correctness
dependency. This module is stdlib-only — no jax import — so the CI perf
gate and the test suite can read databases without a device runtime.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import warnings

SCHEMA_VERSION = 1

#: env var overriding the packaged per-backend database path
ENV_DB = "REPRO_TUNING_DB"

#: pre-tuner hand-picked constants (the deterministic final fallback)
DEFAULTS = {
    "engine": "blocked",        # PrecisionConfig default
    "leaf": None,               # keep the caller's leaf
    "compress_comm": True,      # dist_cholesky default
    "dist_threshold": 2048,     # SolverEngine default
    "max_batch": 32,            # BatchScheduler default
    "max_wait_ms": 5.0,         # async batching window suggestion
}


def ladder_key(cfg) -> str:
    """Canonical ladder name of a PrecisionConfig: ``"bf16_f32"``."""
    return "_".join(cfg.levels)


@dataclasses.dataclass(frozen=True)
class TunedDecision:
    """Resolved knob values for one ``(backend, n, ladder, nshards)``.

    ``source`` records how the lookup resolved: ``"exact"`` (entry hit),
    ``"crossover"`` (engine from the interpolated crossover, other knobs
    from the nearest entry), ``"nearest"`` (nearest-key entry), or
    ``"default"`` (no usable database — today's constants).
    """

    engine: str
    leaf: int | None
    compress_comm: bool
    dist_threshold: int
    max_batch: int
    max_wait_ms: float
    source: str = "default"
    matched_n: int | None = None    # n of the entry the knobs came from

    @classmethod
    def defaults(cls) -> "TunedDecision":
        return cls(**DEFAULTS, source="default")


def _choice_decision(choice: dict, source: str, matched_n=None):
    d = dict(DEFAULTS)
    d.update({k: choice[k] for k in DEFAULTS if choice.get(k) is not None})
    return TunedDecision(**d, source=source, matched_n=matched_n)


# ---------------------------------------------------------------------------
# schema validation
# ---------------------------------------------------------------------------
_ENTRY_KEYS = ("backend", "n", "ladder", "nshards", "choice", "measurements")
_CROSSOVER_KEYS = ("backend", "ladder", "nshards", "knob", "below", "above",
                   "n")


def _finite(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool) \
        and math.isfinite(v)


def validate_db(payload) -> list[str]:
    """Schema check; returns a list of problems (empty = valid).

    Required: ``version``/``backend``/``entries``/``crossovers`` top-level
    keys, at least one entry, every entry fully keyed with finite
    positive timings, every crossover fully keyed (``n`` may be null =
    "never crosses on the measured grid").
    """
    errs = []
    if not isinstance(payload, dict):
        return [f"payload is {type(payload).__name__}, not an object"]
    for k in ("version", "backend", "entries", "crossovers"):
        if k not in payload:
            errs.append(f"missing top-level key {k!r}")
    if errs:
        return errs
    if payload["version"] != SCHEMA_VERSION:
        errs.append(f"version {payload['version']!r} != {SCHEMA_VERSION}")
    entries = payload["entries"]
    if not isinstance(entries, list) or not entries:
        errs.append("entries must be a non-empty list")
        entries = []
    for i, e in enumerate(entries):
        for k in _ENTRY_KEYS:
            if k not in e:
                errs.append(f"entries[{i}]: missing key {k!r}")
        if not isinstance(e.get("choice"), dict):
            errs.append(f"entries[{i}]: choice must be an object")
        elif "engine" not in e["choice"]:
            errs.append(f"entries[{i}]: choice.engine missing")
        meas = e.get("measurements")
        if not isinstance(meas, dict) or not meas:
            errs.append(f"entries[{i}]: measurements must be a non-empty "
                        "object")
            continue
        for name, v in meas.items():
            if name.startswith("us_") and not (_finite(v) and v > 0):
                errs.append(f"entries[{i}]: measurement {name}={v!r} not "
                            "a finite positive time")
    for i, c in enumerate(payload.get("crossovers") or []):
        for k in _CROSSOVER_KEYS:
            if k not in c:
                errs.append(f"crossovers[{i}]: missing key {k!r}")
        n = c.get("n", "missing")
        if n is not None and n != "missing" and not (_finite(n) and n > 0):
            errs.append(f"crossovers[{i}]: n={n!r} not null or positive")
    return errs


# ---------------------------------------------------------------------------
# the database
# ---------------------------------------------------------------------------
class TuningDB:
    """In-memory view of one tuning-database payload."""

    def __init__(self, payload: dict):
        errs = validate_db(payload)
        if errs:
            raise ValueError("invalid tuning DB: " + "; ".join(errs[:5]))
        self.payload = payload
        self.backend = payload["backend"]
        self.entries = payload["entries"]
        self.crossovers = payload["crossovers"]

    # -- lookups -----------------------------------------------------------
    def crossover(self, ladder: str, nshards: int, knob: str = "engine"):
        """The crossover record for ``(ladder, nshards, knob)`` or None."""
        for c in self.crossovers:
            if (c["ladder"] == ladder and c["nshards"] == nshards
                    and c["knob"] == knob):
                return c
        return None

    def _nearest(self, n: int, candidates: list[dict]):
        """Nearest entry by log-space distance in ``n`` (ties: smaller n)."""
        return min(candidates,
                   key=lambda e: (abs(math.log(e["n"]) - math.log(n)),
                                  e["n"]))

    def decide(self, n: int, ladder: str, nshards: int = 1) -> TunedDecision:
        """Resolve knobs for ``(n, ladder, nshards)`` (module docstring
        relaxation order)."""
        same = [e for e in self.entries
                if e["ladder"] == ladder and e["nshards"] == nshards]
        for e in same:
            if e["n"] == n:
                return _choice_decision(e["choice"], "exact", e["n"])
        cx = self.crossover(ladder, nshards)
        if same and cx is not None:
            near = self._nearest(n, same)
            dec = _choice_decision(near["choice"], "crossover", near["n"])
            xn = cx["n"]
            engine = cx["below"] if (xn is None or n < xn) else cx["above"]
            return dataclasses.replace(dec, engine=engine)
        if same:
            near = self._nearest(n, same)
            return _choice_decision(near["choice"], "nearest", near["n"])
        anyl = [e for e in self.entries if e["nshards"] == nshards]
        if anyl:
            near = self._nearest(n, anyl)
            return _choice_decision(near["choice"], "nearest", near["n"])
        return TunedDecision.defaults()


# ---------------------------------------------------------------------------
# loading + the process-wide default database
# ---------------------------------------------------------------------------
def default_db_path(backend: str) -> str:
    """Committed per-backend database path (``REPRO_TUNING_DB`` wins)."""
    env = os.environ.get(ENV_DB)
    if env:
        return env
    return os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "data", f"{backend}.json")


def load_db(path: str, *, warn_missing: bool = True) -> TuningDB | None:
    """Load a database file; corrupt or missing input returns None.

    ``warn_missing=False`` silences the not-found warning (used for
    backends that simply have no committed database yet — that is the
    normal pre-tuning state, not an error).
    """
    if not os.path.exists(path):
        if warn_missing:
            warnings.warn(f"tuning DB not found at {path}; "
                          "falling back to untuned defaults",
                          stacklevel=2)
        return None
    try:
        with open(path, encoding="utf-8") as f:
            payload = json.load(f)
        return TuningDB(payload)
    except (json.JSONDecodeError, ValueError, OSError) as e:
        warnings.warn(f"corrupt tuning DB at {path} ({e}); "
                      "falling back to untuned defaults", stacklevel=2)
        return None


_DB_CACHE: dict[str, TuningDB | None] = {}


def _backend() -> str:
    # runtime-only helper: the CI gates never call it, and the local
    # import keeps module import jax-free
    import jax  # audit: allow(db-stdlib-only)
    return jax.default_backend()


def get_default_db(backend: str | None = None) -> TuningDB | None:
    """The committed database for ``backend`` (cached per process)."""
    backend = backend or _backend()
    if backend not in _DB_CACHE:
        path = default_db_path(backend)
        # only an explicitly-configured path warrants a missing-file
        # warning; an absent packaged DB is the normal untuned state
        _DB_CACHE[backend] = load_db(
            path, warn_missing=bool(os.environ.get(ENV_DB)))
    return _DB_CACHE[backend]


def clear_cache() -> None:
    """Drop cached databases (tests re-point ``REPRO_TUNING_DB``)."""
    _DB_CACHE.clear()


def verify_consultation(db: TuningDB) -> list[str]:
    """Check that lookups actually follow the measured crossovers.

    For every engine crossover in ``db``: a size just below the
    interpolated crossover must resolve to the ``below`` engine (tree)
    and a size just above to the ``above`` engine; a null crossover
    (never crosses on the measured grid) must resolve every measured
    size to the ``below`` engine. Returns a list of violations (empty =
    the engine consults the database correctly). CI's autotune-smoke job
    runs this via ``python -m repro.tune --verify``.
    """
    errs = []
    checked = 0
    for c in db.crossovers:
        if c["knob"] != "engine":
            continue
        lad, ns, xn = c["ladder"], c["nshards"], c["n"]
        grid = sorted(e["n"] for e in db.entries
                      if e["ladder"] == lad and e["nshards"] == ns)
        if not grid:
            errs.append(f"crossover ({lad}, nshards={ns}) has no entries")
            continue
        checked += 1
        if xn is None:
            probes = [(n, c["below"]) for n in grid]
        else:
            probes = [(max(1, int(xn) - 1), c["below"]),
                      (int(xn) + 1, c["above"])]
        for n, want in probes:
            got = db.decide(n, lad, ns).engine
            if got != want:
                errs.append(f"decide(n={n}, {lad}, nshards={ns}) -> "
                            f"{got}, expected {want} "
                            f"(crossover n={xn})")
    if not checked:
        errs.append("no engine crossover found to verify")
    return errs


def decide(n: int, ladder: str, nshards: int = 1, *,
           backend: str | None = None,
           db: TuningDB | None = None) -> TunedDecision:
    """Resolve tuned knobs, falling back to :data:`DEFAULTS`.

    ``db`` overrides the committed database (the test suite and the CI
    verify step inject one); otherwise the per-backend default is used.
    """
    if db is None:
        db = get_default_db(backend)
    if db is None:
        return TunedDecision.defaults()
    return db.decide(n, ladder, nshards)
