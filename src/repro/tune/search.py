"""Measured-search autotuner over the plan/engine knob space.

(Knob space and workflow: docs/TUNING.md.)

:func:`autotune` generates candidate configurations over the knobs that
today's performance hinges on — execution engine (tree vs blocked), leaf
size, distributed-collective compression, the serving batch geometry —
profiles each candidate with the same median-wall-time timer the
benchmarks use, and returns a tuning-database payload
(:mod:`repro.tune.db`) whose entries record both the winning choice and
every raw measurement. Engine winners are additionally interpolated into
a **crossover** size per ``(backend, ladder, nshards)``: the measured
problem size where the blocked engine starts beating the tree engine, so
the n=1024-vs-2048 flip the distributed benchmark exposed is resolved by
measurement instead of a constant.

Everything here is deterministic given deterministic timings: candidates
enumerate in a fixed order, ties break toward the tree engine (the
conservative below-crossover choice) and the smaller knob value, and the
payload carries no timestamps — two runs with identical timer results
produce byte-identical databases (pinned by tests/test_tune.py).

Two defenses keep noise out of the committed database. Competing
candidates are timed **interleaved** (:func:`race`: round-robin rounds,
per-candidate minimum), so transient machine load inflates one round for
everyone instead of one candidate's whole budget. And engine decisions
carry a relative noise tolerance (:data:`REL_TOL`): the blocked engine
must beat the tree by more than timer noise to win a size, both in the
per-entry choice and in the crossover interpolation — otherwise a
statistical tie near the crossover would flip the database run-to-run.
"""
from __future__ import annotations

import functools
import math
import time

import numpy as np

from repro.tune.db import DEFAULTS, SCHEMA_VERSION, validate_db

#: relative timer-noise allowance for engine decisions: blocked must win
#: by more than this margin, else the conservative tree choice stands
REL_TOL = 0.03

#: candidate grids (fixed enumeration order = deterministic tie-breaks)
LEAVES = (128, 256)
ENGINES = ("tree", "blocked")
MAX_BATCHES = (8, 16, 32)
DIST_LEAF = 128         # multi-tile-rows-per-shard regime (bench_dist)

SMOKE_SIZES = (256, 512)
SMOKE_DIST_SIZES = (512, 1024)
FULL_SIZES = (512, 1024, 2048)
FULL_DIST_SIZES = (512, 1024, 2048)


def timeit(fn, *args, warmup=2, iters=5):
    """Median wall-time in microseconds of a jitted callable (mirror of
    ``benchmarks/util.timeit`` — the same timer the perf gates trust)."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def _spd(n, dtype=np.float32, seed=0):
    """Paper §IV-A test matrix (same generator as benchmarks/util.py)."""
    rng = np.random.default_rng(seed)
    m = rng.uniform(-1.0, 1.0, (n, n))
    a = (m + m.T) / 2
    a[np.diag_indices(n)] += n
    return a.astype(dtype)


def interp_crossover(ns, t_tree, t_blocked, rel_tol=REL_TOL):
    """Interpolated n where blocked starts beating tree (log2 space).

    Returns ``None`` when tree holds the top of the grid (never
    crosses for good), the smallest measured n when blocked wins
    everywhere, otherwise the linearly interpolated size at the **last**
    tree->blocked flip — blocked must win every grid point from the
    crossover up, so an isolated sub-scaling blocked "win" at a small
    size (noise) cannot drag the crossover below sizes the tree
    measurably owns. Blocked "wins" a grid point only by more than
    ``rel_tol``. The per-entry engine choices are re-derived from this
    fitted crossover (:func:`autotune`), so exact-entry and crossover
    lookups agree at every measured size by construction.
    """
    # margin over the noise floor; > 0 means blocked measurably wins
    g = [tt - tb - rel_tol * tb for tt, tb in zip(t_tree, t_blocked)]
    if all(x > 0 for x in g):
        return int(ns[0])
    k = max(i for i, x in enumerate(g) if x <= 0)    # last tree win
    if k == len(ns) - 1:
        return None
    lo, hi = math.log2(ns[k]), math.log2(ns[k + 1])
    frac = -g[k] / (g[k + 1] - g[k])
    return int(round(2 ** (lo + frac * (hi - lo))))


def _won(t_tree, t_blocked, rel_tol=REL_TOL) -> str:
    """Engine pick with the noise margin: blocked must beat the tree by
    more than ``rel_tol`` of its own time, else tree stands."""
    return "blocked" if t_tree - t_blocked > rel_tol * t_blocked else "tree"


#: interleaved timing rounds per candidate race: transient machine load
#: inflates one round for every candidate instead of one candidate's
#: whole budget, and the per-candidate minimum discards inflated rounds
RACE_ROUNDS = 3


def race(timer, cands):
    """Time competing candidates round-robin; returns ``{name: us}``.

    ``cands`` is an ordered ``{name: make}`` where ``make()`` builds the
    candidate and returns ``(fn, args)`` — each round gets a **fresh**
    build: for jitted candidates a fresh executable, and fresh argument
    buffers when ``make`` allocates them. Two failure modes of
    sequential one-shot timing motivate this: transient machine load
    lands entirely on whichever candidate ran during it, and a
    compile/allocation layout can come out pathologically slow for one
    candidate and stay sticky for as long as that executable and its
    input buffers live (a ~1.4x penalty observed on the distributed
    blocked engine). Interleaved rounds + per-candidate min over fresh
    builds make the comparison differential and discard both artifacts.
    """
    results = {k: [] for k in cands}
    for _ in range(RACE_ROUNDS):
        for k, make in cands.items():
            fn, args = make()
            results[k].append(timer(fn, *args))
    return {k: min(v) for k, v in results.items()}


# ---------------------------------------------------------------------------
# per-key candidate measurement
# ---------------------------------------------------------------------------
def _tune_single(n, levels, timer, log):
    """Engine x leaf race on the single-device factorization."""
    import jax

    from repro.core.precision import PrecisionConfig
    from repro.core.solve import cholesky
    a = _spd(n)
    cands = {}
    for eng in ENGINES:
        for leaf in LEAVES:
            if n % leaf != 0 or n < leaf:
                continue
            cfg = PrecisionConfig(levels=levels, leaf=leaf, engine=eng)
            cands[f"us_{eng}_leaf{leaf}"] = lambda cfg=cfg: (
                jax.jit(functools.partial(cholesky, cfg=cfg)),
                (jax.device_put(a),))
    meas = {}
    for name, t in race(timer, cands).items():
        meas[name] = round(t, 1)
        eng, leaf = name[3:].rsplit("_leaf", 1)
        log(f"tune_local_n{n}_{eng}_leaf{leaf}", t, "nshards=1")
    # per-engine best (over leaves) feeds both the noise-margined engine
    # pick and the crossover interpolation
    per_engine = {e: min(v for k, v in meas.items()
                         if k.startswith(f"us_{e}_"))
                  for e in ENGINES if any(k.startswith(f"us_{e}_")
                                          for k in meas)}
    eng = _won(per_engine.get("tree", math.inf),
               per_engine.get("blocked", math.inf))
    best = min((k for k in meas if k.startswith(f"us_{eng}_")),
               key=lambda k: (meas[k], k))
    choice = {"engine": eng,
              "leaf": int(best.rsplit("leaf", 1)[1])}
    return choice, meas, per_engine


def _tune_dist(n, levels, nshards, timer, log):
    """Engine + collective-compression race on the distributed path."""
    import dataclasses

    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.distributed import dist_cholesky
    from repro.core.precision import PrecisionConfig
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((nshards,), ("model",))
    cfg = PrecisionConfig(levels=levels, leaf=DIST_LEAF)
    a = _spd(n)
    sharding = NamedSharding(mesh, P("model", None))
    meas = {}
    with mesh:
        # one interleaved race: both local engines on identical
        # full-precision gathers, plus the compressed collective on the
        # blocked engine (its f32 side == the blocked candidate above)
        def make(cfg_e, cc):
            return lambda: (
                jax.jit(functools.partial(dist_cholesky, mesh=mesh,
                                          cfg=cfg_e, compress_comm=cc)),
                (jax.device_put(a, sharding),))
        cands = {}
        for eng in ENGINES:
            cfg_e = dataclasses.replace(cfg, engine=eng)
            cands[f"us_local_{eng}"] = make(cfg_e, False)
        cands["us_comm_compressed"] = make(cfg, True)
        for name, t in race(timer, cands).items():
            meas[name] = round(t, 1)
            log(f"tune_dist_n{n}_{name[3:]}", t, f"nshards={nshards}")
        meas["us_comm_f32"] = meas["us_local_blocked"]
    choice = {
        "engine": _won(meas["us_local_tree"], meas["us_local_blocked"]),
        "leaf": DIST_LEAF,
        "compress_comm": meas["us_comm_compressed"] <= meas["us_comm_f32"],
    }
    per_engine = {e: meas[f"us_local_{e}"] for e in ENGINES}
    return choice, meas, per_engine


def _tune_serving(levels, timer, log, *, n=256, n_rhs=16):
    """Scheduler batch-geometry race: chunked multi-RHS refine calls."""
    from repro.core.precision import PrecisionConfig
    from repro.serve import SolveOptions, SolverEngine
    cfg = PrecisionConfig(levels=levels, leaf=128)
    eng = SolverEngine(cfg, max_sweeps=4)
    a = _spd(n, seed=3)
    rng = np.random.default_rng(4)
    bs = [rng.standard_normal(n).astype(np.float32) for _ in range(n_rhs)]
    cands = {}
    for mb in MAX_BATCHES:
        def run(mb=mb):
            xs = []
            for i in range(0, n_rhs, mb):
                x, _ = eng.solve_batched(
                    a, bs[i:i + mb],
                    SolveOptions(target_digits=4, cache_key="tune"))
                xs.extend(x)
            return xs
        cands[f"us_serve_batch{mb}"] = lambda run=run: (run, ())
    meas = {}
    for name, t in race(timer, cands).items():
        meas[name] = round(t, 1)
        log(f"tune_serve_batch{name.rsplit('batch', 1)[1]}_n{n}", t,
            f"n_rhs={n_rhs}")
    best = min(MAX_BATCHES,
               key=lambda mb: (meas[f"us_serve_batch{mb}"], mb))
    # batching window sized to one solve call: a request never waits
    # longer than the latency of the work it would join
    t1 = timer(lambda: eng.solve(a, bs[0], SolveOptions(
        target_digits=4, cache_key="tune"))[0])
    meas["us_serve_single"] = round(t1, 1)
    max_wait_ms = float(min(50.0, max(1.0, round(t1 / 1e3, 1))))
    return {"max_batch": int(best), "max_wait_ms": max_wait_ms}, meas


def _refit_engines(entries, ladder, nshards, xn):
    """Re-derive each entry's engine from the fitted crossover side.

    The per-size :func:`_won` votes feed the crossover fit; the fit then
    overrides any vote it treated as noise (e.g. an isolated blocked win
    at a small size below sizes the tree measurably owns), so
    exact-entry and crossover lookups agree at every measured size. The
    raw measurements stay untouched in the entry.
    """
    for e in entries:
        if e["ladder"] != ladder or e["nshards"] != nshards:
            continue
        want = "blocked" if xn is not None and e["n"] >= xn else "tree"
        if e["choice"]["engine"] != want:
            e["choice"]["engine"] = want
            meas = e["measurements"]
            leaves = [k for k in meas if k.startswith(f"us_{want}_leaf")]
            if leaves:     # single-device entries race leaf sizes too
                best = min(leaves, key=lambda k: (meas[k], k))
                e["choice"]["leaf"] = int(best.rsplit("leaf", 1)[1])


# ---------------------------------------------------------------------------
# the search driver
# ---------------------------------------------------------------------------
def autotune(backend=None, *, ladders=("bf16_f32",), sizes=None,
             dist_sizes=None, smoke=False, timer=None, nshards=None,
             serving=True, log=None):
    """Run the measured search; returns a tuning-database payload.

    ``timer(fn, *args) -> us`` is injectable (tests pass a deterministic
    fake; the default is the benchmark median timer). ``nshards`` is the
    distributed mesh width (default: the device count when >= 2; the
    distributed knobs are skipped on single-device sessions).
    ``ladders`` entries are canonical ladder keys (``"bf16_f32"``).
    """
    import jax

    backend = backend or jax.default_backend()
    sizes = tuple(sizes or (SMOKE_SIZES if smoke else FULL_SIZES))
    dist_sizes = tuple(dist_sizes
                       or (SMOKE_DIST_SIZES if smoke else FULL_DIST_SIZES))
    if timer is None:
        timer = functools.partial(timeit, warmup=1 if smoke else 2,
                                  iters=3 if smoke else 7)
    if log is None:
        def log(name, us, derived):
            print(f"{name},{us:.1f},{derived}")
    if nshards is None:
        nshards = jax.device_count() if jax.device_count() >= 2 else 0

    entries, crossovers = [], []
    for ladder in ladders:
        levels = tuple(ladder.split("_"))
        serve_choice, serve_meas = ({}, {})
        if serving:
            serve_choice, serve_meas = _tune_serving(levels, timer, log)
        # -- single-device grid --------------------------------------------
        singles = {}
        for n in sizes:
            choice, meas, per_engine = _tune_single(n, levels, timer, log)
            singles[n] = per_engine
            choice.update(serve_choice)
            meas.update(serve_meas if n == sizes[0] else {})
            entries.append({"backend": backend, "n": n, "ladder": ladder,
                            "nshards": 1, "choice": choice,
                            "measurements": meas})
        grid = sorted(singles)
        xn = interp_crossover(grid,
                              [singles[n]["tree"] for n in grid],
                              [singles[n]["blocked"] for n in grid])
        crossovers.append({
            "backend": backend, "ladder": ladder, "nshards": 1,
            "knob": "engine", "below": "tree", "above": "blocked",
            "n": xn})
        _refit_engines(entries, ladder, 1, xn)
        # -- distributed grid ----------------------------------------------
        if nshards >= 2:
            dists = {}
            for n in dist_sizes:
                if n % (nshards * DIST_LEAF) != 0:
                    continue
                choice, meas, per_engine = _tune_dist(n, levels, nshards,
                                                      timer, log)
                dists[n] = per_engine
                entries.append({"backend": backend, "n": n,
                                "ladder": ladder, "nshards": nshards,
                                "choice": choice, "measurements": meas})
            if dists:
                grid = sorted(dists)
                xn = interp_crossover(grid,
                                      [dists[n]["tree"] for n in grid],
                                      [dists[n]["blocked"] for n in grid])
                crossovers.append({
                    "backend": backend, "ladder": ladder,
                    "nshards": nshards, "knob": "engine", "below": "tree",
                    "above": "blocked", "n": xn})
                _refit_engines(entries, ladder, nshards, xn)
                log(f"tune_crossover_{ladder}_p{nshards}", 0.0,
                    f"engine_crossover_n={xn}")
        # dist_threshold: smallest n where the distributed path beats the
        # best single-device engine; a grid where it never wins keeps the
        # conservative default (a forced host mesh measures collective
        # overhead, not a verdict on real multi-chip meshes)
        thr = DEFAULTS["dist_threshold"]
        if nshards >= 2:
            wins = [n for n in sorted(set(sizes) & set(dist_sizes))
                    if min(dists.get(n, {}).values() or [float("inf")])
                    < min(singles[n].values())]
            if wins:
                thr = int(wins[0])
        for e in entries:
            if e["ladder"] == ladder:
                e["choice"].setdefault("dist_threshold", thr)

    payload = {"version": SCHEMA_VERSION, "backend": backend,
               "smoke": bool(smoke), "sizes": list(sizes),
               "nshards_dist": nshards if nshards >= 2 else None,
               "entries": entries, "crossovers": crossovers}
    errs = validate_db(payload)
    assert not errs, f"autotune produced an invalid DB: {errs}"
    return payload
