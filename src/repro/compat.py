"""Version-compat shims for jax APIs that moved between releases.

The repo targets the jax that ships in the image; these helpers keep it
importable across the 0.4.x -> 0.5+ API moves without scattering
version checks through the solver code.
"""
from __future__ import annotations

import jax

try:
    shard_map = jax.shard_map  # jax >= 0.4.38 exports it at top level
except AttributeError:
    from jax.experimental.shard_map import shard_map  # noqa: F401


def vma_of(x) -> frozenset:
    """Varying-manual-axes of ``x`` (empty set before jax grew `typeof`,
    where shard_map had no vma tracking and promotion is a no-op)."""
    typeof = getattr(jax, "typeof", None)
    if typeof is None:
        return frozenset()
    return getattr(typeof(x), "vma", frozenset())


def pvary(x, axes):
    """`jax.lax.pvary` where it exists; identity on older jax (whose
    shard_map accepts collectives over unvaried axes directly)."""
    fn = getattr(jax.lax, "pvary", None)
    return fn(x, axes) if fn is not None else x
