"""Model backbone: stacked-layer scan over blocks, all families.

Families (ModelConfig.family):
  dense   — attention + MLP (nemotron, gemma, granite)
  vlm     — dense backbone, first n_img_tokens positions fed by projected
            patch embeddings (pixtral stub frontend)
  audio   — dense backbone over summed codebook embeddings, per-codebook
            logit heads (musicgen stub frontend)
  moe     — MLA attention + (shared+routed) MoE FFN (deepseek v2/v3);
            first ``moe_first_dense`` layers use a dense MLP
  rwkv    — RWKV-6 blocks (attention-free)
  hybrid  — Mamba-2 blocks with one *param-shared* attention+MLP block
            applied every ``attn_every`` layers (zamba2)

Execution modes:
  train   — full sequence, no KV caches materialized (remat-friendly)
  prefill — full sequence, returns per-layer caches of length S
  decode  — one token at position ``pos`` against caller-provided caches

Layers are stacked ([L, ...] leaves) and executed with lax.scan (+ per
layer remat) so the HLO stays O(1) in depth — required for the 96-layer
dry-runs to compile in reasonable time.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba2, mla, moe, rwkv6
from repro.models.common import (ModelConfig, NO_SHARD, Sharder, _init,
                                 cross_entropy, mlp_apply, mlp_params,
                                 rms_norm)

AUX_LOSS_W = 0.01


# ---------------------------------------------------------------------------
# parameter init (vmapped over layers => stacked [L, ...] leaves)
# ---------------------------------------------------------------------------
def _tf_layer_params(rng, cfg: ModelConfig, *, use_moe: bool):
    k1, k2 = jax.random.split(rng)
    p = {"ln1": jnp.zeros((cfg.d_model,), cfg.pdt),
         "ln2": jnp.zeros((cfg.d_model,), cfg.pdt)}
    p["attn"] = (mla.mla_params(k1, cfg) if cfg.mla
                 else attn.attn_params(k1, cfg))
    if use_moe:
        p["moe"] = moe.moe_params(k2, cfg)
    else:
        p["mlp"] = mlp_params(k2, cfg.d_model, cfg.d_ff, cfg.mlp, cfg.pdt)
    return p


def init_params(rng, cfg: ModelConfig):
    ks = jax.random.split(rng, 8)
    d = cfg.d_model
    params: dict[str, Any] = {
        "final_ln": jnp.zeros((d,), cfg.pdt),
    }
    if cfg.family == "audio":
        params["embed"] = _init(ks[0], (cfg.n_codebooks, cfg.vocab, d),
                                cfg.pdt)
        params["lm_head"] = _init(ks[1], (cfg.n_codebooks, d, cfg.vocab),
                                  cfg.pdt)
    else:
        params["embed"] = _init(ks[0], (cfg.vocab, d), cfg.pdt)
        params["lm_head"] = _init(ks[1], (d, cfg.vocab), cfg.pdt)
    if cfg.family == "vlm":
        params["patch_proj"] = _init(ks[2], (d, d), cfg.pdt)

    L = cfg.n_layers
    if cfg.family == "rwkv":
        params["layers"] = jax.vmap(
            lambda k: rwkv6.rwkv_params(k, cfg))(jax.random.split(ks[3], L))
    elif cfg.family == "hybrid":
        params["layers"] = jax.vmap(
            lambda k: mamba2.mamba_params(k, cfg))(jax.random.split(ks[3], L))
        params["shared_attn"] = _tf_layer_params(ks[4], cfg, use_moe=False)
    elif cfg.family == "moe":
        nd = cfg.moe_first_dense
        if nd:
            params["dense_layers"] = jax.vmap(
                lambda k: _tf_layer_params(k, cfg, use_moe=False))(
                    jax.random.split(ks[5], nd))
        params["layers"] = jax.vmap(
            lambda k: _tf_layer_params(k, cfg, use_moe=True))(
                jax.random.split(ks[3], L - nd))
    else:
        params["layers"] = jax.vmap(
            lambda k: _tf_layer_params(k, cfg, use_moe=False))(
                jax.random.split(ks[3], L))
    return params


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------
def _tf_block(x, p, cfg: ModelConfig, sharder: Sharder, *, use_moe: bool,
              pos=None, cache=None):
    """Returns (x, kv_or_cache, aux).

    Sequence-parallel discipline: the residual stream x is seq-sharded;
    norms run in the sharded domain (row-local); the seq all-gather is
    pinned to the *bf16 norm output* via act_full — without the pin the
    SPMD partitioner reshards the norm's f32 internals, doubling the
    gather/all-reduce bytes (perf note A1, docs/ARCHITECTURE.md; nemotron-340b)."""
    # The act_full pin helps exactly when attention is head-sharded over
    # the model axis (the big-TP archs: −61 % collectives on
    # nemotron-340b, perf note A1); when heads don't divide the axis (gemma's
    # 8 heads on 16-way TP) the pin forces gathers GSPMD would otherwise
    # avoid (+3.2x collectives measured) — so it is conditional.
    pin = sharder._fits(cfg.n_heads) if cfg.n_heads else False

    def norm_then_gather(x, gamma):
        h = rms_norm(x, gamma, cfg.norm_eps)
        if not pin:
            return h
        # pin the bf16 norm output seq-sharded FIRST, then gather: the
        # collective moves a bf16 tensor between two pinned points, and
        # the norm's f32 internals can never be the gathered operand
        return sharder.act_full(sharder.act_bsd(h))

    h = norm_then_gather(x, p["ln1"])
    attn_fn = mla.mla_attention if cfg.mla else attn.attention
    a, kv = attn_fn(h, p["attn"], cfg, sharder, pos=pos, cache=cache)
    # constrain the branch output seq-sharded BEFORE the residual add:
    # the TP contraction's all-reduce becomes a reduce-scatter (half the
    # bytes) and the add runs fully in the sharded domain (perf note A3)
    x = x + (sharder.act_bsd(a) if pin else a)
    h = norm_then_gather(x, p["ln2"])
    aux = jnp.float32(0.0)
    if use_moe:
        f, aux = moe.moe_ffn(h, p["moe"], cfg, sharder)
    else:
        f = mlp_apply(h, p["mlp"]["w_in"], p["mlp"].get("w_gate"),
                      p["mlp"]["w_out"], cfg.mlp, sharder)
    x = (x + sharder.act_bsd(f)) if pin else sharder.act_bsd(x + f)
    return x, kv, aux


# ---------------------------------------------------------------------------
# embedding / heads (modality stubs live here)
# ---------------------------------------------------------------------------
def embed_inputs(params, batch, cfg: ModelConfig, sharder: Sharder, *,
                 decode: bool = False):
    if cfg.family == "audio":
        toks = batch["tokens"]            # [B, S, n_codebooks]
        x = sum(jnp.take(params["embed"][i], toks[..., i], axis=0)
                for i in range(cfg.n_codebooks))
    else:
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
    if cfg.family == "vlm" and cfg.n_img_tokens and not decode:
        # stub frontend: precomputed patch embeddings occupy the first
        # n_img positions (projected into the backbone width)
        pe = jnp.einsum("bnd,de->bne", batch["patch_embeds"],
                        params["patch_proj"]).astype(x.dtype)
        n = cfg.n_img_tokens
        x = jnp.concatenate([pe[:, :n], x[:, n:]], axis=1)
    return sharder.act_bsd(x.astype(cfg.adt))


def lm_logits(params, x, cfg: ModelConfig, sharder: Sharder):
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    if cfg.family == "audio":
        out = jnp.einsum("bsd,cdv->bscv", x, params["lm_head"])
        return out.astype(jnp.float32)
    out = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return sharder.logits(out).astype(jnp.float32)


# ---------------------------------------------------------------------------
# scan helpers
# ---------------------------------------------------------------------------
def _scan(x, stacked, fn, cfg: ModelConfig, carries=None, collect=False):
    """Scan ``fn(x, p_l, c_l) -> (x, out_l, aux)`` over stacked layers.

    carries: stacked per-layer states (xs input) or None.
    collect : stack per-layer outputs (prefill kv / updated caches).
    """
    def body(carry, inp):
        x, aux = carry
        p_l, c_l = inp
        x, out_l, a = fn(x, p_l, c_l)
        return (x, aux + a), (out_l if collect else None)

    wrapped = jax.checkpoint(body) if cfg.remat else body
    (x, aux), outs = jax.lax.scan(
        wrapped, (x, jnp.float32(0.0)), (stacked, carries))
    return x, aux, outs


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def forward(params, batch, cfg: ModelConfig, sharder: Sharder = NO_SHARD,
            *, mode: str = "train", caches=None, pos=None,
            last_only: bool = False):
    """Returns (logits, aux_loss, new_caches).

    mode='train'  : caches/pos ignored; new_caches is None (or final SSM
                    states for recurrent families — they are cheap).
    mode='prefill': new_caches hold per-layer KV (length S) / SSM states.
    mode='decode' : batch tokens have S=1; ``caches`` required; ``pos`` is
                    the absolute write/attend position (scalar int32).
    last_only     : compute logits for the final position only (prefill
                    serving path — avoids the [B, S, V] tensor).
    """
    assert mode in ("train", "prefill", "decode"), mode
    decode = mode == "decode"
    collect = mode != "train"
    x = embed_inputs(params, batch, cfg, sharder, decode=decode)
    B = x.shape[0]

    def head(params, x):
        return lm_logits(params, x[:, -1:] if last_only else x, cfg,
                         sharder)

    if cfg.family == "rwkv":
        def fn(x, p_l, c_l):
            y, s = rwkv6.rwkv_block(x, p_l, cfg, sharder, state=c_l)
            return y, s, jnp.float32(0.0)
        states = caches if caches is not None else _stacked_states(
            lambda: rwkv6.init_rwkv_state(cfg, B, dtype=cfg.adt),
            cfg.n_layers)
        # recurrent states are tiny: always carry & collect them
        x, aux, new_states = _scan(x, params["layers"], fn, cfg, states,
                                   collect=True)
        return head(params, x), aux, new_states

    if cfg.family == "hybrid":
        return _forward_hybrid(params, x, cfg, sharder, mode=mode,
                               caches=caches, pos=pos, head=head)

    # transformer families ------------------------------------------------
    aux_total = jnp.float32(0.0)
    new_caches: dict[str, Any] = {}
    blk_pos = pos if decode else None

    def make_fn(use_moe):
        def fn(x, p_l, c_l):
            return _tf_block(x, p_l, cfg, sharder, use_moe=use_moe,
                             pos=blk_pos, cache=c_l)
        return fn

    if cfg.family == "moe" and cfg.moe_first_dense:
        c = caches["dense"] if decode else None
        x, aux, nc = _scan(x, params["dense_layers"], make_fn(False), cfg,
                           c, collect=collect)
        aux_total += aux
        new_caches["dense"] = nc

    use_moe = cfg.family == "moe"
    c = caches["main"] if decode else None
    x, aux, nc = _scan(x, params["layers"], make_fn(use_moe), cfg, c,
                       collect=collect)
    aux_total += aux
    new_caches["main"] = nc
    return (head(params, x), aux_total,
            new_caches if collect else None)


def _stacked_states(mk, n):
    one = mk()
    return jax.tree.map(
        lambda t: jnp.broadcast_to(t[None], (n,) + t.shape), one)


def _forward_hybrid(params, x, cfg: ModelConfig, sharder: Sharder, *,
                    mode, caches=None, pos=None, head=None):
    """Zamba2: groups of mamba blocks with one shared attention block
    between groups (params shared; each application has its own cache)."""
    B = x.shape[0]
    decode = mode == "decode"
    k = cfg.attn_every or cfg.n_layers
    n_apps = max(cfg.n_layers // k, 1)
    Lg = cfg.n_layers // n_apps
    if caches is not None:
        m_states, a_caches = caches["mamba"], caches["attn"]
    else:
        m_states = _stacked_states(
            lambda: mamba2.init_mamba_state(cfg, B, dtype=cfg.adt),
            cfg.n_layers)
        a_caches = None

    def fn(x, p_l, c_l):
        y, s = mamba2.mamba_block(x, p_l, cfg, sharder, state=c_l)
        return y, s, jnp.float32(0.0)

    collect = mode != "train"
    new_m, new_a = [], []
    blk_pos = pos if decode else None

    def shared_block(x, p, ac):
        return _tf_block(x, p, cfg, sharder, use_moe=False, pos=blk_pos,
                         cache=ac)

    if cfg.remat:
        # the shared block runs outside the layer scan; without its own
        # checkpoint every application's attention intermediates are
        # live until backward (zamba2 perf note B2: 47 GiB/dev baseline)
        shared_block = jax.checkpoint(shared_block)

    for g in range(n_apps):
        sl = jax.tree.map(lambda t: t[g * Lg:(g + 1) * Lg], params["layers"])
        st = jax.tree.map(lambda t: t[g * Lg:(g + 1) * Lg], m_states)
        x, _, ns = _scan(x, sl, fn, cfg, st, collect=collect)
        new_m.append(ns)
        ac = (jax.tree.map(lambda t: t[g], a_caches)
              if decode else None)
        x, kv, _ = shared_block(x, params["shared_attn"], ac)
        new_a.append(kv)
    if collect:
        new_caches = {
            "mamba": jax.tree.map(lambda *ts: jnp.concatenate(ts, 0),
                                  *new_m),
            "attn": jax.tree.map(lambda *ts: jnp.stack(ts, 0), *new_a),
        }
    else:
        new_caches = None
    logits = (head(params, x) if head is not None
              else lm_logits(params, x, cfg, sharder))
    return logits, jnp.float32(0.0), new_caches


def pad_caches(caches, to_len: int):
    """Grow prefill caches (length S) to a decode buffer of ``to_len``.

    Only sequence-indexed attention leaves (k/v/c/kr) are padded; SSM
    states carry no sequence axis and pass through unchanged.
    """
    SEQ_LEAVES = {"k", "v", "c", "kr"}

    def pad(path, leaf):
        key = path[-1].key if hasattr(path[-1], "key") else None
        if key in SEQ_LEAVES and leaf.ndim >= 4:
            s = leaf.shape[2]
            if s < to_len:
                cfgpad = [(0, 0)] * leaf.ndim
                cfgpad[2] = (0, to_len - s)
                return jnp.pad(leaf, cfgpad)
        return leaf

    return jax.tree_util.tree_map_with_path(pad, caches)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------
def loss_fn(params, batch, cfg: ModelConfig, sharder: Sharder = NO_SHARD):
    logits, aux, _ = forward(params, batch, cfg, sharder, mode="train")
    if cfg.family == "audio":
        losses = [cross_entropy(logits[:, :, i], batch["labels"][..., i])
                  for i in range(cfg.n_codebooks)]
        ce = sum(losses) / cfg.n_codebooks
    else:
        ce = cross_entropy(logits, batch["labels"])
    return ce + AUX_LOSS_W * aux, {"ce": ce, "aux": aux}
