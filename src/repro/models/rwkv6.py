"""RWKV-6 "Finch" block (attention-free, data-dependent decay).

Time mixing per head (head dim N):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t          (state: N x N per head)
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
with w_t = exp(-exp(w0 + LoRA(x~_t))) a *data-dependent* per-channel
decay (the Finch contribution), token-shift interpolation on every
projection input, and a gated output. Channel mixing is the standard
RWKV squared-ReLU FFN.

Training/prefill run a lax.scan over time carrying (state, last token);
decode is a single recurrence step — O(1) memory in sequence length,
which is why rwkv6-3b runs the long_500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, Sharder, _init, rms_norm

LORA_R = 64


def rwkv_params(rng, cfg: ModelConfig):
    d = cfg.d_model
    N = cfg.ssm_head_dim
    H = d // N
    ks = jax.random.split(rng, 16)
    p = {
        # token-shift mixing coefficients (per-channel, for r/k/v/w/g)
        "mu": jnp.zeros((5, d), cfg.pdt),
        "wr": _init(ks[0], (d, d), cfg.pdt),
        "wk": _init(ks[1], (d, d), cfg.pdt),
        "wv": _init(ks[2], (d, d), cfg.pdt),
        "wg": _init(ks[3], (d, d), cfg.pdt),
        "wo": _init(ks[4], (d, d), cfg.pdt),
        "w0": jnp.zeros((d,), cfg.pdt),             # base decay
        "w_lora_a": _init(ks[5], (d, LORA_R), cfg.pdt),
        "w_lora_b": _init(ks[6], (LORA_R, d), cfg.pdt, scale=0.01),
        "u": jnp.zeros((H, N), cfg.pdt),            # bonus
        "ln_x": jnp.zeros((d,), cfg.pdt),
        # channel mixing
        "mu_c": jnp.zeros((2, d), cfg.pdt),
        "ck": _init(ks[7], (d, cfg.d_ff), cfg.pdt),
        "cv": _init(ks[8], (cfg.d_ff, d), cfg.pdt),
        "cr": _init(ks[9], (d, d), cfg.pdt),
        "ln1": jnp.zeros((d,), cfg.pdt),
        "ln2": jnp.zeros((d,), cfg.pdt),
    }
    return p


def _shift_mix(x, x_prev, mu):
    """Token shift: lerp(x_t, x_{t-1}, mu). x: [B,S,D]; x_prev: [B,D]."""
    shifted = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
    return x + (shifted - x) * mu


TIME_CHUNK = 128


def _time_mix_scan(r, k, v, w, u, state0):
    """r/k/v: [B,S,H,N]; w: [B,S,H,N] decay in (0,1); state0: [B,H,N,N].
    Returns (out [B,S,H,N], state_T).

    Two-level scan: the outer scan carries state across TIME_CHUNK-sized
    chunks and checkpoints each chunk, so the backward pass stores
    S/TIME_CHUNK states instead of S (the classic RNN-remat trick —
    without it a 4k-token train step would save 4096 per-step states).
    """
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                        # [B,H,N]
        kv = k_t[..., :, None] * v_t[..., None, :]      # [B,H,N,N]
        # bonus term (u ⊙ k_t)ᵀ v_t contracts with r_t to a per-head scalar
        bonus = jnp.sum(r_t * u[None] * k_t, axis=-1, keepdims=True)
        o = jnp.einsum("bhn,bhnm->bhm", r_t, s) + bonus * v_t
        s = w_t[..., :, None] * s + kv
        return s, o

    B, S, H, N = r.shape
    ck = min(TIME_CHUNK, S)
    if S % ck:
        ck = 1
    nc = S // ck

    @jax.checkpoint
    def chunk(s, inp):
        xs = tuple(jnp.moveaxis(t, 1, 0) for t in inp)  # [ck,B,H,N]
        s, out = jax.lax.scan(step, s, xs)
        return s, jnp.moveaxis(out, 0, 1)               # [B,ck,H,N]

    resh = lambda t: jnp.moveaxis(t.reshape(B, nc, ck, H, N), 1, 0)
    state, outs = jax.lax.scan(chunk, state0,
                               tuple(resh(t) for t in (r, k, v, w)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, H, N)
    return out, state


def rwkv_block(x, p, cfg: ModelConfig, sharder: Sharder, *, state=None):
    """One full RWKV block (time mix + channel mix).

    state: {"s": [B,H,N,N], "x_tm": [B,D], "x_cm": [B,D]} or None (zeros).
    Returns (y, new_state)."""
    B, S, d = x.shape
    N = cfg.ssm_head_dim
    H = d // N
    if state is None:
        state = init_rwkv_state(cfg, B, dtype=x.dtype)

    xn = rms_norm(x, p["ln1"], cfg.norm_eps)
    mu = p["mu"][:, None, None, :].astype(x.dtype)      # [5,1,1,D]
    xr, xk, xv, xw, xg = (_shift_mix(xn, state["x_tm"], mu[i])
                          for i in range(5))
    r = jnp.einsum("bsd,de->bse", xr, p["wr"]).reshape(B, S, H, N)
    k = jnp.einsum("bsd,de->bse", xk, p["wk"]).reshape(B, S, H, N)
    v = jnp.einsum("bsd,de->bse", xv, p["wv"]).reshape(B, S, H, N)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["wg"]))
    r = sharder.act_heads(r)

    dec = p["w0"].astype(jnp.float32) + jnp.einsum(
        "bsd,dr,re->bse", xw.astype(jnp.float32),
        p["w_lora_a"].astype(jnp.float32), p["w_lora_b"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(dec)).reshape(B, S, H, N).astype(x.dtype)

    out, s_new = _time_mix_scan(r, k, v, w, p["u"].astype(x.dtype),
                                state["s"])
    out = rms_norm(out.reshape(B, S, d), p["ln_x"], cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", out * g.astype(out.dtype), p["wo"])
    y = x + out

    # channel mixing
    yn = rms_norm(y, p["ln2"], cfg.norm_eps)
    mu_c = p["mu_c"][:, None, None, :].astype(x.dtype)
    xck = _shift_mix(yn, state["x_cm"], mu_c[0])
    xcr = _shift_mix(yn, state["x_cm"], mu_c[1])
    kk = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xck, p["ck"])))
    kk = sharder.act_ffn(kk)
    cm = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xcr, p["cr"])) * \
        jnp.einsum("bsf,fd->bsd", kk, p["cv"]).astype(x.dtype)
    y = sharder.act_bsd(y + cm.astype(y.dtype))

    new_state = {"s": s_new, "x_tm": xn[:, -1, :], "x_cm": yn[:, -1, :]}
    return y, new_state


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype=None):
    dtype = dtype or cfg.adt
    N = cfg.ssm_head_dim
    H = cfg.d_model // N
    return {"s": jnp.zeros((batch, H, N, N), dtype),
            "x_tm": jnp.zeros((batch, cfg.d_model), dtype),
            "x_cm": jnp.zeros((batch, cfg.d_model), dtype)}
