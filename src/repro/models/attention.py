"""GQA/MQA/MHA attention with flash-style chunked softmax and KV cache.

Training/prefill uses an online-softmax scan over KV chunks (constant
memory in sequence length — required for the prefill_32k cells); decode
is a single grouped einsum against the cache. GQA is computed in grouped
form [B, S, KV, G, hd] so key/value heads are never materialized
repeated.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import (ModelConfig, Sharder, _init, apply_rope,
                                 rope_freqs)

NEG_INF = -1e30


def attn_params(rng, cfg: ModelConfig):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    ks = jax.random.split(rng, 4)
    return {
        "wq": _init(ks[0], (d, H * hd), cfg.pdt),
        "wk": _init(ks[1], (d, KV * hd), cfg.pdt),
        "wv": _init(ks[2], (d, KV * hd), cfg.pdt),
        "wo": _init(ks[3], (H * hd, d), cfg.pdt),
    }


def _chunked_causal(q, k, v, *, q_pos0, chunk):
    """Online-softmax causal attention.

    q: [B, S, KV, G, hd]; k/v: [B, T, KV, hd]. q_pos0: absolute position
    of q[.., 0] (k/v positions start at 0). Returns [B, S, KV, G, hd].
    """
    B, S, KV, G, hd = q.shape
    T = k.shape[1]
    chunk = min(chunk, T)
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk
    scale = hd ** -0.5
    qf = q.astype(jnp.float32) * scale

    kc = k.reshape(B, nc, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nc, chunk, KV, hd).transpose(1, 0, 2, 3, 4)

    m0 = jnp.full((B, S, KV, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, S, KV, G), jnp.float32)
    a0 = jnp.zeros((B, S, KV, G, hd), jnp.float32)
    qpos = q_pos0 + jnp.arange(S)

    # The chunk step is checkpointed: without it the scan's backward
    # saves the stacked per-chunk score tensors — the full S x T
    # attention matrix, which chunking exists to avoid (flash-attention
    # backward = recompute scores per chunk). Measured as perf note B4 (docs/ARCHITECTURE.md).
    @jax.checkpoint
    def step(carry, inp):
        ci, k_c, v_c = inp
        m, l, acc = carry
        s = jnp.einsum("bskgh,bckh->bskgc", qf, k_c.astype(jnp.float32))
        kpos = ci * chunk + jnp.arange(chunk)
        mask = qpos[:, None] >= kpos[None, :]          # [S, chunk]
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bskgc,bckh->bskgh", p, v_c.astype(jnp.float32))
        return (m_new, l, acc), None

    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (jnp.arange(nc), kc, vc))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype)


def _decode_attn(q, k_cache, v_cache, *, pos):
    """q: [B, 1, KV, G, hd]; caches: [B, Smax, KV, hd]; attends to <= pos."""
    B, _, KV, G, hd = q.shape
    Smax = k_cache.shape[1]
    scale = hd ** -0.5
    s = jnp.einsum("bqkgh,btkh->bkgqt", q.astype(jnp.float32) * scale,
                   k_cache.astype(jnp.float32))
    valid = jnp.arange(Smax)[None, :] <= pos                   # [1, Smax]
    s = jnp.where(valid[None, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqt,btkh->bqkgh", p, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


def attention(x, p, cfg: ModelConfig, sharder: Sharder, *, pos=None,
              cache=None, chunk=1024):
    """Self-attention. Modes:
      train/prefill : pos=None — full causal over x; returns (out, kv)
      decode        : pos = scalar position; cache = {'k','v'} updated.
    """
    B, S, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    G = H // KV
    q = jnp.einsum("bsd,dq->bsq", x, p["wq"]).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,dq->bsq", x, p["wk"]).reshape(B, S, KV, hd)
    v = jnp.einsum("bsd,dq->bsq", x, p["wv"]).reshape(B, S, KV, hd)
    q = sharder.act_heads(q)

    pos0 = 0 if pos is None else pos
    positions = (jnp.arange(S) + pos0) if pos is None else (
        jnp.full((S,), pos0))
    cos, sin = rope_freqs(positions, hd, cfg.rope_theta)
    cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    qg = q.reshape(B, S, KV, G, hd)
    if pos is None:
        out = _chunked_causal(qg, k, v, q_pos0=0, chunk=chunk)
        kv = {"k": k, "v": v}
    else:
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
        out = _decode_attn(qg, k_cache, v_cache, pos=pos)
        kv = {"k": k_cache, "v": v_cache}
    out = out.reshape(B, S, H * hd)
    out = jnp.einsum("bsq,qd->bsd", out, p["wo"])
    return out, kv


def init_kv_cache(cfg: ModelConfig, batch: int, length: int, dtype=None):
    dtype = dtype or cfg.adt
    shape = (batch, length, cfg.n_kv, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
