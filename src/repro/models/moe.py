"""Mixture-of-Experts FFN (DeepSeek style: shared + routed, top-k).

Expert parallelism: experts are sharded over the ``model`` mesh axis.
Each (data, model) device routes its *local* tokens, keeps only the
assignments that hit its local experts (sorted by local expert id into a
static-capacity buffer), runs the expert FFNs as a grouped GEMM with
``jax.lax.ragged_dot`` (TPU MegaBlocks analogue), scatters back weighted
by the gates, and psums the partial outputs over the model axis. No
all-to-all, no [tokens, experts, capacity] dispatch tensor.

Capacity: C = ceil(T * topk / EP * capacity_factor); overflow tokens are
dropped (standard GShard semantics) — ragged_dot zero-fills rows past the
group sums so drops are exact zeros, and the shared experts (always
dense) keep every token covered.

On a laptop (no mesh) the same code runs with EP=1, which makes it an
exact dropless reference when capacity_factor covers all assignments —
tests exploit this against a dense per-expert loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.compat import shard_map
from repro.models.common import ModelConfig, Sharder, _init


def moe_params(rng, cfg: ModelConfig):
    d, E, F = cfg.d_model, cfg.moe_experts, cfg.moe_dff or cfg.d_ff
    ks = jax.random.split(rng, 5)
    p = {
        "router": _init(ks[0], (d, E), jnp.float32),      # router in f32
        "w_in": _init(ks[1], (E, d, F), cfg.pdt),
        "w_gate": _init(ks[2], (E, d, F), cfg.pdt),
        "w_out": _init(ks[3], (E, F, d), cfg.pdt),
    }
    if cfg.moe_shared:
        Fs = F * cfg.moe_shared
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {"w_in": _init(k1, (d, Fs), cfg.pdt),
                       "w_gate": _init(k2, (d, Fs), cfg.pdt),
                       "w_out": _init(k3, (Fs, d), cfg.pdt)}
    return p


def _expert_ffn_local(x_rows, w_in, w_gate, w_out, group_sizes):
    """Grouped SwiGLU over sorted rows: ragged_dot per expert group."""
    h = jax.lax.ragged_dot(x_rows, w_in, group_sizes)
    g = jax.lax.ragged_dot(x_rows, w_gate, group_sizes)
    h = jax.nn.silu(g) * h
    return jax.lax.ragged_dot(h.astype(x_rows.dtype), w_out, group_sizes)


def _moe_local(x, router_w, w_in, w_gate, w_out, *, cfg: ModelConfig,
               ep: int, axis: str | None, all_axes: tuple = ()):
    """Per-device MoE. x: [B_loc, S, D]; expert weights are the local
    shard [E/ep, D, F]. Returns the *partial* output (psum over axis).
    ``all_axes``: every mesh axis name — the scalar aux loss is pmean'd
    over all of them so its out_spec can be fully replicated."""
    B, S, D = x.shape
    T = B * S
    K = cfg.moe_topk
    E = cfg.moe_experts
    e_loc = E // ep
    my = jax.lax.axis_index(axis) if axis else 0

    xf = x.reshape(T, D)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, K)                   # [T, K]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    flat_e = idx.reshape(T * K)
    flat_g = gate.reshape(T * K)
    flat_t = jnp.repeat(jnp.arange(T), K)

    local = (flat_e // e_loc) == my
    key = jnp.where(local, flat_e % e_loc, e_loc)         # non-local last
    order = jnp.argsort(key)

    cap = int(-(-T * K // ep) * cfg.moe_capacity_factor)
    cap = max(min(cap, T * K), 1)
    sel = order[:cap]
    sel_key = key[sel]                                    # sorted ascending
    rows = xf[flat_t[sel]]                                # [cap, D]
    counts = jnp.bincount(jnp.where(sel_key < e_loc, sel_key, e_loc),
                          length=e_loc + 1)[:e_loc]
    out_rows = _expert_ffn_local(rows, w_in, w_gate, w_out,
                                 counts.astype(jnp.int32))
    # rows beyond sum(counts) are zero (ragged_dot) => exact drop
    weighted = out_rows * flat_g[sel][:, None].astype(out_rows.dtype)
    out = jnp.zeros((T, D), out_rows.dtype).at[flat_t[sel]].add(weighted)
    if axis:
        out = jax.lax.psum(out, axis)
    # router aux (load-balance) loss terms, averaged later
    me = probs.mean(axis=0)                               # [E]
    ce = jnp.zeros((E,), jnp.float32).at[flat_e].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce)
    if all_axes:
        # aux varies over the batch axes but is invarying over 'model'
        # (x is replicated there); promote the missing axes, then mean
        # over everything so the out_spec can be fully replicated.
        have = compat.vma_of(aux)
        missing = tuple(a for a in all_axes if a not in have)
        if missing:
            aux = compat.pvary(aux, missing)
        aux = jax.lax.pmean(aux, all_axes)
    return out.reshape(B, S, D).astype(x.dtype), aux


def moe_ffn(x, p, cfg: ModelConfig, sharder: Sharder):
    """Full MoE block: routed experts (+ shared experts dense path)."""
    if sharder.enabled:
        mesh = sharder.mesh
        assert mesh is not None, "Sharder.mesh required for sharded MoE"
        ep = mesh.shape[sharder.model_axis]
        pspec_x = P(sharder.batch_axes, None, None)
        fn = functools.partial(_moe_local, cfg=cfg, ep=ep,
                               axis=sharder.model_axis,
                               all_axes=tuple(mesh.axis_names))
        routed, aux = shard_map(
            fn, mesh=mesh,
            in_specs=(pspec_x, P(None, None),
                      P(sharder.model_axis, None, None),
                      P(sharder.model_axis, None, None),
                      P(sharder.model_axis, None, None)),
            out_specs=(pspec_x, P()),
        )(x, p["router"], p["w_in"], p["w_gate"], p["w_out"])
    else:
        routed, aux = _moe_local(x, p["router"], p["w_in"], p["w_gate"],
                                 p["w_out"], cfg=cfg, ep=1, axis=None)
    if cfg.moe_shared:
        sp = p["shared"]
        h = jnp.einsum("bsd,df->bsf", x, sp["w_in"])
        g = jnp.einsum("bsd,df->bsf", x, sp["w_gate"])
        h = jax.nn.silu(g).astype(h.dtype) * h
        h = sharder.act_ffn(h)
        routed = routed + jnp.einsum("bsf,fd->bsd", h, sp["w_out"])
    return routed, aux


def moe_ffn_dense_reference(x, p, cfg: ModelConfig):
    """O(E)-cost dropless reference (tests only): every expert computes
    every token densely; outputs combined with the top-k gates."""
    B, S, D = x.shape
    T = B * S
    xf = x.reshape(T, D)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, cfg.moe_topk)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    h = jnp.einsum("td,edf->tef", xf, p["w_in"])
    g = jnp.einsum("td,edf->tef", xf, p["w_gate"])
    h = jax.nn.silu(g) * h
    y = jnp.einsum("tef,efd->ted", h.astype(xf.dtype), p["w_out"])
    mask = jax.nn.one_hot(idx, cfg.moe_experts, dtype=jnp.float32)  # [T,K,E]
    w = jnp.einsum("tk,tke->te", gate, mask)
    out = jnp.einsum("te,ted->td", w.astype(y.dtype), y)
    return out.reshape(B, S, D)
