"""Shared model machinery: config, sharding helper, norms, MLPs, RoPE."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

DTYPES = {"f16": jnp.float16, "bf16": jnp.bfloat16, "f32": jnp.float32}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config covers every assigned architecture family."""
    name: str
    family: str                  # dense | moe | rwkv | hybrid | audio | vlm
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    n_heads: int = 0
    n_kv: int = 0
    head_dim: int = 0            # 0 -> d_model // n_heads
    mlp: str = "swiglu"          # swiglu | geglu | relu2 | gelu
    # --- MoE ---
    moe_experts: int = 0
    moe_topk: int = 0
    moe_shared: int = 0
    moe_dff: int = 0
    moe_capacity_factor: float = 1.25
    moe_first_dense: int = 0     # deepseek: first k layers stay dense
    # --- MLA (deepseek) ---
    mla: bool = False
    kv_lora: int = 0
    q_lora: int = 0
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128
    # --- SSM (rwkv6 / mamba2) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    # --- hybrid (zamba2): one shared attention block every k ssm blocks ---
    attn_every: int = 0
    # --- modality stubs ---
    n_img_tokens: int = 0        # pixtral: positions fed by patch embeddings
    n_codebooks: int = 0         # musicgen: EnCodec streams
    # --- numerics / execution ---
    param_dtype: str = "f32"
    activ_dtype: str = "f32"
    remat: bool = True
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    max_seq: int = 8192          # KV-cache length for serving

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def adt(self):
        return DTYPES[self.activ_dtype]

    @property
    def pdt(self):
        return DTYPES[self.param_dtype]

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class Sharder:
    """Applies GSPMD sharding constraints when a mesh is active.

    Logical axes: 'batch' -> (pod, data), 'seq'/'ffn'/'heads'/'vocab' ->
    model, 'layers' -> stacked-layer FSDP axis. On a laptop (no mesh) it
    is the identity, so models run unmodified in smoke tests.
    """
    enabled: bool = False
    batch_axes: Any = ("data",)   # ('pod','data') on the multi-pod mesh
    model_axis: str = "model"
    fsdp_axis: str | None = "data"   # parameter (ZeRO-3) sharding axis
    mesh: Any = None                 # concrete Mesh (needed by shard_map ops)

    def c(self, x, spec: P):
        if not self.enabled:
            return x
        return jax.lax.with_sharding_constraint(x, spec)

    def _msize(self) -> int:
        if self.mesh is None or self.model_axis is None:
            return 1
        return self.mesh.shape[self.model_axis]

    def _fits(self, dim: int) -> bool:
        m = self._msize()
        return m > 1 and dim % m == 0

    # activation specs (divisibility-aware: a dim that doesn't divide the
    # model axis simply stays replicated — gemma's 8 heads on a 16-way TP
    # axis, decode's seq=1, etc.) ---------------------------------------
    def act_bsd(self, x):        # [batch, seq, d_model] — seq-sharded (SP)
        seq = self.model_axis if self._fits(x.shape[1]) else None
        return self.c(x, P(self.batch_axes, seq, None))

    def act_full(self, x):       # [batch, seq, d_model] — replicated d/seq
        return self.c(x, P(self.batch_axes, None, None))

    def act_heads(self, x):      # [batch, seq, heads, hd] — TP over heads
        if self._fits(x.shape[2]):
            return self.c(x, P(self.batch_axes, None, self.model_axis, None))
        if self._fits(x.shape[3]):
            return self.c(x, P(self.batch_axes, None, None, self.model_axis))
        return self.c(x, P(self.batch_axes, None, None, None))

    def act_ffn(self, x):        # [batch, seq, d_ff] — TP over ffn
        f = self.model_axis if self._fits(x.shape[2]) else None
        return self.c(x, P(self.batch_axes, None, f))

    def logits(self, x):         # [batch, seq, vocab] — TP over vocab
        v = self.model_axis if self._fits(x.shape[-1]) else None
        return self.c(x, P(self.batch_axes, *(None,) * (x.ndim - 2), v))


NO_SHARD = Sharder(enabled=False)


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------
def rms_norm(x, gamma, eps):
    """RMSNorm with f32 *statistics* but no full-width f32 tensor: the
    variance reduction runs in f32 (numerics), the normalization stays in
    x.dtype. Materializing x.astype(f32) puts a [B,S,D] f32 tensor right
    at the sequence-parallel reshard point and doubles the collective
    bytes (perf note A4, docs/ARCHITECTURE.md; nemotron-340b)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                   keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * (1.0 + gamma.astype(x.dtype))


def rope_freqs(positions, dim, theta):
    """positions: [...] int -> (cos, sin) of shape [..., dim//2]."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: [..., dim] with trailing head dim; cos/sin broadcastable [..., dim/2]."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mlp_apply(x, w_in, w_gate, w_out, kind: str, sharder: Sharder):
    """Gated / plain MLP. w_gate is None for non-gated kinds."""
    h = jnp.einsum("bsd,df->bsf", x, w_in)
    if kind in ("swiglu", "geglu"):
        g = jnp.einsum("bsd,df->bsf", x, w_gate)
        act = jax.nn.silu(g) if kind == "swiglu" else jax.nn.gelu(g)
        h = act.astype(h.dtype) * h
    elif kind == "relu2":       # nemotron squared-ReLU
        h = jnp.square(jax.nn.relu(h))
    elif kind == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(kind)
    h = sharder.act_ffn(h)
    return jnp.einsum("bsf,fd->bsd", h, w_out)


def mlp_params(rng, d, f, kind, dtype):
    k1, k2, k3 = jax.random.split(rng, 3)
    p = {"w_in": _init(k1, (d, f), dtype),
         "w_out": _init(k2, (f, d), dtype)}
    if kind in ("swiglu", "geglu"):
        p["w_gate"] = _init(k3, (d, f), dtype)
    return p


def _init(rng, shape, dtype, scale=None):
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(rng, shape, jnp.float32) * scale).astype(dtype)


init_dense = _init


def cross_entropy(logits, labels, *, z_loss=1e-4):
    """Standard LM loss with z-regularization; labels -100 are masked."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    mask = labels >= 0
    safe = jnp.maximum(labels, 0)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (lse - ll) + z_loss * jnp.square(lse)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
