"""Model zoo: every assigned architecture family in composable JAX."""
from repro.models.common import ModelConfig, NO_SHARD, Sharder  # noqa: F401
from repro.models import transformer  # noqa: F401
