"""Multi-head Latent Attention (DeepSeek V2/V3).

Faithful structure: queries (optionally LoRA-compressed), a shared
compressed KV latent of width ``kv_lora`` plus a decoupled RoPE key of
width ``rope_head_dim``. The serving cache stores ONLY the latent and the
rope key (the MLA memory advantage); decode uses the absorbed-weight
formulation (q absorbed through W_uk, output through W_uv) so the
per-head keys are never materialized at decode time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import _chunked_causal, NEG_INF
from repro.models.common import (ModelConfig, Sharder, _init, apply_rope,
                                 rope_freqs, rms_norm)


def mla_params(rng, cfg: ModelConfig):
    d, H = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(rng, 8)
    p = {
        "w_dkv": _init(ks[0], (d, cfg.kv_lora), cfg.pdt),
        "kv_norm": jnp.zeros((cfg.kv_lora,), cfg.pdt),
        "w_uk": _init(ks[1], (cfg.kv_lora, H * dn), cfg.pdt),
        "w_uv": _init(ks[2], (cfg.kv_lora, H * dv), cfg.pdt),
        "w_kr": _init(ks[3], (d, dr), cfg.pdt),
        "wo": _init(ks[4], (H * dv, d), cfg.pdt),
    }
    if cfg.q_lora:
        p["w_dq"] = _init(ks[5], (d, cfg.q_lora), cfg.pdt)
        p["q_norm"] = jnp.zeros((cfg.q_lora,), cfg.pdt)
        p["w_uq"] = _init(ks[6], (cfg.q_lora, H * (dn + dr)), cfg.pdt)
    else:
        p["wq"] = _init(ks[7], (d, H * (dn + dr)), cfg.pdt)
    return p


def _queries(x, p, cfg: ModelConfig):
    B, S, _ = x.shape
    H, dn, dr = cfg.n_heads, cfg.nope_head_dim, cfg.rope_head_dim
    if cfg.q_lora:
        cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dq"]),
                      p["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsr,rq->bsq", cq, p["w_uq"])
    else:
        q = jnp.einsum("bsd,dq->bsq", x, p["wq"])
    q = q.reshape(B, S, H, dn + dr)
    return q[..., :dn], q[..., dn:]


def mla_attention(x, p, cfg: ModelConfig, sharder: Sharder, *, pos=None,
                  cache=None, chunk=1024):
    B, S, d = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    q_nope, q_rope = _queries(x, p, cfg)
    q_nope = sharder.act_heads(q_nope)

    c_kv = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dkv"]),
                    p["kv_norm"], cfg.norm_eps)                 # [B,S,R]
    k_rope = jnp.einsum("bsd,dr->bsr", x, p["w_kr"])            # [B,S,dr]

    pos0 = 0 if pos is None else pos
    positions = (jnp.arange(S) + pos0) if pos is None else (
        jnp.full((S,), pos0))
    cos, sin = rope_freqs(positions, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos[None, :, None, :], sin[None, :, None, :])
    k_rope = apply_rope(k_rope, cos[None, :, :], sin[None, :, :])

    if pos is None:
        # train/prefill: decompress per-head k/v, run shared flash path.
        k_nope = jnp.einsum("bsr,rq->bsq", c_kv,
                            p["w_uk"]).reshape(B, S, H, dn)
        v = jnp.einsum("bsr,rq->bsq", c_kv, p["w_uv"]).reshape(B, S, H, dv)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, dr))],
            axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        # pad v to k's head dim for the shared kernel, trim after
        pad = (dn + dr) - dv
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad)))
        out = _chunked_causal(q[:, :, :, None, :].transpose(0, 1, 2, 3, 4)
                              .reshape(B, S, H, 1, dn + dr),
                              k, vp, q_pos0=0, chunk=chunk)
        out = out.reshape(B, S, H, dn + dr)[..., :dv]
        new_cache = {"c": c_kv, "kr": k_rope}
    else:
        # absorbed decode: score = q_nope W_uk^T . c  +  q_rope . k_rope
        c_cache = jax.lax.dynamic_update_slice(
            cache["c"], c_kv.astype(cache["c"].dtype), (0, pos, 0))
        kr_cache = jax.lax.dynamic_update_slice(
            cache["kr"], k_rope.astype(cache["kr"].dtype), (0, pos, 0))
        w_uk = p["w_uk"].reshape(cfg.kv_lora, H, dn)
        q_abs = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32))            # [B,1,H,R]
        s = (jnp.einsum("bshr,btr->bhst", q_abs,
                        c_cache.astype(jnp.float32))
             + jnp.einsum("bshr,btr->bhst", q_rope.astype(jnp.float32),
                          kr_cache.astype(jnp.float32)))
        s = s * ((dn + dr) ** -0.5)
        Smax = c_cache.shape[1]
        valid = jnp.arange(Smax)[None, :] <= pos
        s = jnp.where(valid[None, None, :, :], s, NEG_INF)
        pattn = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhst,btr->bshr", pattn,
                           c_cache.astype(jnp.float32))         # [B,1,H,R]
        w_uv = p["w_uv"].reshape(cfg.kv_lora, H, dv)
        out = jnp.einsum("bshr,rhv->bshv", o_lat,
                         w_uv.astype(jnp.float32)).astype(x.dtype)
        new_cache = {"c": c_cache, "kr": kr_cache}
    out = out.reshape(B, S, H * dv)
    return jnp.einsum("bsq,qd->bsd", out, p["wo"]), new_cache


def init_mla_cache(cfg: ModelConfig, batch: int, length: int, dtype=None):
    dtype = dtype or cfg.adt
    return {"c": jnp.zeros((batch, length, cfg.kv_lora), dtype),
            "kr": jnp.zeros((batch, length, cfg.rope_head_dim), dtype)}
