"""Mamba-2 (SSD) block for the Zamba-2 hybrid architecture.

State-space: per head h with head dim P and state dim N,
    S_t = a_t * S_{t-1} + dt_t * B_t x_t^T        (S: [N, P])
    y_t = C_t S_t + D x_t
with a_t = exp(-dt_t * exp(A_log_h)) a data-dependent scalar decay per
head (Mamba-2's scalar-identity A). Projections follow the mamba2 layout:
one in_proj producing (z, x, B, C, dt), grouped RMSNorm before out_proj,
silu gating.

Implementation: chunked scan — within a chunk of length Q the recurrence
is evaluated with the quadratic "attention form" (MXU-friendly), across
chunks a lax.scan carries the state. Q=128 default keeps the quadratic
term tiny while the chunk GEMMs are MXU-aligned. Decode is the O(1)
single-step recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, Sharder, _init, rms_norm

EXPAND = 2
CHUNK = 128


def _dims(cfg: ModelConfig):
    d_inner = EXPAND * cfg.d_model
    P = cfg.ssm_head_dim
    H = d_inner // P
    N = cfg.ssm_state
    return d_inner, H, P, N


def mamba_params(rng, cfg: ModelConfig):
    d = cfg.d_model
    d_inner, H, P, N = _dims(cfg)
    ks = jax.random.split(rng, 4)
    conv_dim = d_inner + 2 * N  # x, B, C go through the short conv
    return {
        "ln": jnp.zeros((d,), cfg.pdt),
        "w_in": _init(ks[0], (d, 2 * d_inner + 2 * N + H), cfg.pdt),
        "conv_w": _init(ks[1], (4, conv_dim), cfg.pdt),   # depthwise, k=4
        "A_log": jnp.zeros((H,), cfg.pdt),
        "D": jnp.ones((H,), cfg.pdt),
        "dt_bias": jnp.zeros((H,), cfg.pdt),
        "ssm_norm": jnp.zeros((d_inner,), cfg.pdt),
        "w_out": _init(ks[2], (d_inner, d), cfg.pdt),
    }


def _dw_conv(x, w, x_prev):
    """Depthwise causal conv, kernel 4. x: [B,S,C]; x_prev: [B,3,C] carry.
    Returns (y, new carry)."""
    full = jnp.concatenate([x_prev, x], axis=1)          # [B, S+3, C]
    k = w.shape[0]
    y = sum(full[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(k))
    return y, full[:, -3:, :]


def _ssd_chunk_scan(xh, bmat, cmat, dt, a, state0):
    """Chunked SSD recurrence.

    xh: [B,S,H,P] inputs; bmat/cmat: [B,S,N]; dt: [B,S,H] (>0);
    a:  [B,S,H] per-step decay in (0,1]; state0: [B,H,N,P].
    Returns (y [B,S,H,P], state_T).
    """
    B, S, H, P = xh.shape
    Q = min(CHUNK, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    def chunk(state, inp):
        x_c, b_c, c_c, dt_c, a_c = inp    # [B,Q,...]
        la = jnp.log(jnp.maximum(a_c, 1e-37))            # [B,Q,H]
        cum = jnp.cumsum(la, axis=1)                     # prefix sums
        # intra-chunk "attention" term: y_t += sum_{u<=t} C_t.B_u decay x_u
        seg = cum[:, :, None, :] - cum[:, None, :, :]    # [B,Q,Q,H] = sum_(u,t]
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        gamma = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)
        cb = jnp.einsum("btn,bun->btu", c_c.astype(jnp.float32),
                        b_c.astype(jnp.float32))         # [B,Q,Q]
        mat = cb[..., None] * gamma                      # [B,Q,Q,H]
        xdt = x_c.astype(jnp.float32) * dt_c[..., None]  # [B,Q,H,P]
        y = jnp.einsum("btuh,buhp->bthp", mat, xdt)
        # inter-chunk: contribution of carried-in state
        decay_in = jnp.exp(cum)                          # [B,Q,H]
        y = y + jnp.einsum("btn,bhnp,bth->bthp",
                           c_c.astype(jnp.float32), state,
                           decay_in)
        # state update: S' = a_total * S + sum_u decay_(u,T] dt_u B_u x_u^T
        tot = cum[:, -1, :]                              # [B,H]
        decay_out = jnp.exp(tot[:, None, :] - cum)       # [B,Q,H]
        upd = jnp.einsum("bun,buhp,buh->bhnp",
                         b_c.astype(jnp.float32), xdt, decay_out)
        state = jnp.exp(tot)[:, :, None, None] * state + upd
        return state, y

    resh = lambda t: jnp.moveaxis(
        t.reshape(B, nc, Q, *t.shape[2:]), 1, 0)
    xs = (resh(xh), resh(bmat), resh(cmat), resh(dt), resh(a))
    state, ys = jax.lax.scan(chunk, state0.astype(jnp.float32), xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, P)
    return y.astype(xh.dtype), state


def mamba_block(x, p, cfg: ModelConfig, sharder: Sharder, *, state=None):
    """Full Mamba-2 block with residual. state: {"ssm","conv"} or None."""
    B, S, d = x.shape
    d_inner, H, P, N = _dims(cfg)
    if state is None:
        state = init_mamba_state(cfg, B, dtype=x.dtype)

    xn = rms_norm(x, p["ln"], cfg.norm_eps)
    zxbcdt = jnp.einsum("bsd,de->bse", xn, p["w_in"])
    z, xc, bmat, cmat, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N],
        axis=-1)
    conv_in = jnp.concatenate([xc, bmat, cmat], axis=-1)
    conv_out, conv_state = _dw_conv(conv_in, p["conv_w"].astype(x.dtype),
                                    state["conv"])
    conv_out = jax.nn.silu(conv_out)
    xc, bmat, cmat = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))     # [B,S,H]
    a = jnp.exp(-dt * jnp.exp(p["A_log"].astype(jnp.float32)))   # (0,1]
    xh = xc.reshape(B, S, H, P)
    xh = sharder.act_heads(xh)
    y, ssm_state = _ssd_chunk_scan(xh, bmat, cmat, dt, a, state["ssm"])
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * \
        xh.astype(jnp.float32)
    y = y.astype(x.dtype).reshape(B, S, d_inner)
    y = rms_norm(y * jax.nn.silu(z).astype(y.dtype), p["ssm_norm"],
                 cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    new_state = {"ssm": ssm_state.astype(state["ssm"].dtype),
                 "conv": conv_state}
    # seq-shard the residual between blocks (SP): without this the remat
    # checkpoint of every layer input is replicated over the model axis
    # (zamba2 train_4k baseline: 47 GiB/dev; see docs/ARCHITECTURE.md, "Performance notes" B1)
    return sharder.act_bsd(x + out), new_state


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=None):
    dtype = dtype or cfg.adt
    d_inner, H, P, N = _dims(cfg)
    return {"ssm": jnp.zeros((batch, H, N, P), jnp.float32),
            "conv": jnp.zeros((batch, 3, d_inner + 2 * N), dtype)}
