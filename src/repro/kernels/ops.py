"""Public jit'd kernel API with backend dispatch.

This is the TPU analogue of the paper's Julia multiple-dispatch layer: a
single call site (`ops.qgemm`, `ops.potrf`, ...) resolves to

  * the Pallas TPU kernel when running on TPU (`impl="pallas"`),
  * the Pallas kernel in interpret mode for correctness work
    (`impl="interpret"`),
  * the pure-jnp oracle (XLA fused) on CPU/GPU (`impl="jnp"`).

Default is "auto": pallas on TPU, jnp elsewhere. Override globally with
REPRO_KERNEL_IMPL={pallas,interpret,jnp} or per-call with ``impl=``.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import qgemm as _qgemm
from repro.kernels import panel as _panel
from repro.kernels import potrf as _potrf
from repro.kernels import residual as _residual
from repro.kernels import syrk as _syrk
from repro.kernels import trsm as _trsm
from repro.kernels import ref as _ref

_VALID = ("auto", "pallas", "interpret", "jnp")


def resolve_impl(impl: str | None = None) -> str:
    impl = impl or os.environ.get("REPRO_KERNEL_IMPL", "auto")
    assert impl in _VALID, impl
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return impl


def qgemm(a, b, scale=1.0, *, c=None, beta=0.0, trans_b=False,
          out_dtype=jnp.float32, impl=None, **tiles):
    impl = resolve_impl(impl)
    if impl == "jnp":
        return _ref.qgemm_ref(a, b, trans_b=trans_b, scale=scale, c=c,
                              beta=beta, out_dtype=out_dtype)
    return _qgemm.qgemm(a, b, scale, c=c, beta=beta, trans_b=trans_b,
                        out_dtype=out_dtype,
                        interpret=(impl == "interpret"), **tiles)


def potrf(a, *, impl=None):
    impl = resolve_impl(impl)
    if impl == "jnp":
        return _ref.potrf_ref(a)
    return _potrf.potrf_leaf(a, interpret=(impl == "interpret"))


def tri_inv(l, *, impl=None):
    impl = resolve_impl(impl)
    if impl == "jnp":
        return _ref.tri_inv_ref(l)
    return _potrf.tri_inv_leaf(l, interpret=(impl == "interpret"))


def trsm(b, l, *, side="right", trans=True, linv=None, impl=None):
    """Triangular solve. ``linv`` takes a precomputed ``tri_inv(l)`` —
    callers that solve repeatedly against one factor (cholesky_solve's
    two sweeps, K-FAC steps, the serve factor cache) pay the leaf
    inversion once instead of per call."""
    impl = resolve_impl(impl)
    if impl == "jnp" and linv is None:
        return _ref.trsm_ref(b, l, side=side, trans=trans)
    if side == "right" and trans:
        if impl == "jnp":
            return _ref.qgemm_ref(b, linv, trans_b=True, out_dtype=b.dtype)
        return _trsm.trsm_leaf(b, l, linv=linv,
                               interpret=(impl == "interpret"))
    # Left-side leaf solves reduce to the right-side kernel by transposition:
    #   L^{-1} B   = (B^T L^{-T})^T
    #   L^{-T} B   = (B^T L^{-1})^T = ((L^{-1} B^T... ) use inv directly
    if linv is None:
        linv = tri_inv(l, impl=impl)
    if side == "left" and not trans:
        return qgemm(linv.astype(b.dtype), b, impl=impl,
                     out_dtype=b.dtype)
    if side == "left" and trans:
        return qgemm(linv.T.astype(b.dtype), b, impl=impl,
                     out_dtype=b.dtype)
    raise NotImplementedError(f"trsm side={side} trans={trans}")


def residual(a, x, b, *, impl=None, **tiles):
    """Fused IR residual r = b - a @ x (the refinement sweep hot path).

    f64 operands always take the jnp oracle: the MXU has no f64 and the
    fused kernel's f32 accumulator would silently eat the extra digits.
    """
    impl = resolve_impl(impl)
    if impl == "jnp" or any(jnp.dtype(v.dtype) == jnp.float64
                            for v in (a, x, b)):
        return _ref.residual_ref(a, x, b)
    return _residual.residual_fused(a, x, b,
                                    interpret=(impl == "interpret"),
                                    **tiles)


def panel_update(linv, a21, c, *, store_names, store_quants, pair_names,
                 pair_quants, rounding=True, impl=None):
    """Fused panel TRSM + trailing SYRK for the blocked executor.

    One dispatch applies ``L21 = A21 @ L11^-T`` and ``C -= L21 L21^T``
    (lower tiles only) with the plan's per-tile precision metadata.
    f64 containers take the jnp oracle (no f64 on the MXU), like
    :func:`residual`. Returns ``(l21, c_updated)``.
    """
    impl = resolve_impl(impl)
    if impl == "jnp" or any(jnp.dtype(v.dtype) == jnp.float64
                            for v in (linv, a21, c)):
        return _ref.panel_update_ref(
            linv, a21, c, store_names=store_names,
            store_quants=store_quants, pair_names=pair_names,
            pair_quants=pair_quants, rounding=rounding)
    return _panel.panel_update(
        linv, a21, c, store_names=store_names, store_quants=store_quants,
        pair_names=pair_names, pair_quants=pair_quants, rounding=rounding,
        interpret=(impl == "interpret"))


def syrk(c, a, scale=1.0, beta=1.0, *, packed=False, impl=None, **tiles):
    impl = resolve_impl(impl)
    if impl == "jnp":
        return _ref.syrk_ref(c, a, alpha=1.0, beta=beta, scale=scale)
    fn = _syrk.syrk_packed if packed else _syrk.syrk_leaf
    return fn(c, a, scale, beta, interpret=(impl == "interpret"), **tiles)
