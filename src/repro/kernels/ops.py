"""Public jit'd kernel API with backend dispatch.

This is the TPU analogue of the paper's Julia multiple-dispatch layer: a
single call site (`ops.qgemm`, `ops.potrf`, ...) resolves to

  * the Pallas TPU kernel when running on TPU (`impl="pallas"`),
  * the Pallas kernel in interpret mode for correctness work
    (`impl="interpret"`),
  * the pure-jnp oracle (XLA fused) on CPU/GPU (`impl="jnp"`).

Default is "auto": pallas on TPU, jnp elsewhere. Override globally with
REPRO_KERNEL_IMPL={pallas,interpret,jnp} or per-call with ``impl=``.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import qgemm as _qgemm
from repro.kernels import potrf as _potrf
from repro.kernels import residual as _residual
from repro.kernels import syrk as _syrk
from repro.kernels import trsm as _trsm
from repro.kernels import ref as _ref

_VALID = ("auto", "pallas", "interpret", "jnp")


def resolve_impl(impl: str | None = None) -> str:
    impl = impl or os.environ.get("REPRO_KERNEL_IMPL", "auto")
    assert impl in _VALID, impl
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return impl


def qgemm(a, b, scale=1.0, *, c=None, beta=0.0, trans_b=False,
          out_dtype=jnp.float32, impl=None, **tiles):
    impl = resolve_impl(impl)
    if impl == "jnp":
        return _ref.qgemm_ref(a, b, trans_b=trans_b, scale=scale, c=c,
                              beta=beta, out_dtype=out_dtype)
    return _qgemm.qgemm(a, b, scale, c=c, beta=beta, trans_b=trans_b,
                        out_dtype=out_dtype,
                        interpret=(impl == "interpret"), **tiles)


def potrf(a, *, impl=None):
    impl = resolve_impl(impl)
    if impl == "jnp":
        return _ref.potrf_ref(a)
    return _potrf.potrf_leaf(a, interpret=(impl == "interpret"))


def tri_inv(l, *, impl=None):
    impl = resolve_impl(impl)
    if impl == "jnp":
        return _ref.tri_inv_ref(l)
    return _potrf.tri_inv_leaf(l, interpret=(impl == "interpret"))


def trsm(b, l, *, side="right", trans=True, impl=None):
    impl = resolve_impl(impl)
    if impl == "jnp":
        return _ref.trsm_ref(b, l, side=side, trans=trans)
    if side == "right" and trans:
        return _trsm.trsm_leaf(b, l, interpret=(impl == "interpret"))
    # Left-side leaf solves reduce to the right-side kernel by transposition:
    #   L^{-1} B   = (B^T L^{-T})^T
    #   L^{-T} B   = (B^T L^{-1})^T = ((L^{-1} B^T... ) use inv directly
    linv = tri_inv(l, impl=impl)
    if side == "left" and not trans:
        return qgemm(linv.astype(b.dtype), b, impl=impl,
                     out_dtype=b.dtype)
    if side == "left" and trans:
        return qgemm(linv.T.astype(b.dtype), b, impl=impl,
                     out_dtype=b.dtype)
    raise NotImplementedError(f"trsm side={side} trans={trans}")


def residual(a, x, b, *, impl=None, **tiles):
    """Fused IR residual r = b - a @ x (the refinement sweep hot path).

    f64 operands always take the jnp oracle: the MXU has no f64 and the
    fused kernel's f32 accumulator would silently eat the extra digits.
    """
    impl = resolve_impl(impl)
    if impl == "jnp" or any(jnp.dtype(v.dtype) == jnp.float64
                            for v in (a, x, b)):
        return _ref.residual_ref(a, x, b)
    return _residual.residual_fused(a, x, b,
                                    interpret=(impl == "interpret"),
                                    **tiles)


def syrk(c, a, scale=1.0, beta=1.0, *, packed=False, impl=None, **tiles):
    impl = resolve_impl(impl)
    if impl == "jnp":
        return _ref.syrk_ref(c, a, alpha=1.0, beta=beta, scale=scale)
    fn = _syrk.syrk_packed if packed else _syrk.syrk_leaf
    return fn(c, a, scale, beta, interpret=(impl == "interpret"), **tiles)
