"""Flash-attention Pallas kernel (causal, GQA-aware).

The model zoo's hottest layer: the pure-JAX scan in models/attention.py
is the oracle; this kernel is the TPU-native version — online softmax
with the (m, l, acc) state in VMEM scratch, grid (batch*heads, q-block,
kv-block) with the kv dimension innermost so the running state carries
across kv steps. Fully-masked kv blocks are skipped with pl.when (the
causal lower triangle costs ~half the blocks). GQA never materializes
repeated K/V: the kv index_map divides the head index by the group size.

VMEM per program: q (bq, hd) + k/v (bk, hd) + acc (bq, hd) f32 + m/l
(bq, 128): bq=bk=256, hd<=256 => ~1.2 MB << 16 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _HAS_PLTPU = True
except ImportError:  # pragma: no cover
    pltpu = None
    _HAS_PLTPU = False

DEFAULT_BQ = 256
DEFAULT_BK = 256
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, nk, bq, bk, causal):
    kb = pl.program_id(2)
    qb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: kv block strictly after the q block contributes nothing
    run = (kb * bk <= qb * bq + (bq - 1)) if causal else True

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        if causal:
            qi = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            ki = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qi >= ki, s, NEG_INF)
        m_prev = m_ref[:, :1]                             # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)                    # (bq, 1)
        l_ref[:, :1] = l_ref[:, :1] * corr + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[:, :1] = m_new

    @pl.when(kb == nk - 1)
    def _fin():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal=True, bq=DEFAULT_BQ, bk=DEFAULT_BK,
                    interpret=False):
    """q: [H, S, hd]; k/v: [KV, T, hd] with H = KV * G (GQA).

    Returns [H, S, hd]. S/T padded to block multiples internally (the
    padded kv rows are masked by the causal test / a length mask).
    """
    H, S, hd = q.shape
    KV, T, _ = k.shape
    assert H % KV == 0, (H, KV)
    G = H // KV
    scale = hd ** -0.5
    bq = min(bq, S)
    bk = min(bk, T)
    Sp, Tp = (-(-S // bq)) * bq, (-(-T // bk)) * bk
    if Sp != S:
        q = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0)))
    if Tp != T:
        # pad keys so padded positions never win the max: since callers
        # use causal attention with T == S, padded kv rows are masked by
        # the causal test; for the non-causal path we mask via -inf keys.
        k = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0)),
                    constant_values=0.0)
        v = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0)))
    assert causal or Tp == T, "non-causal path requires T % bk == 0"

    nq, nk = Sp // bq, Tp // bk
    grid = (H, nq, nk)
    scratch = ([pltpu.VMEM((bq, 128), jnp.float32),
                pltpu.VMEM((bq, 128), jnp.float32),
                pltpu.VMEM((bq, hd), jnp.float32)] if _HAS_PLTPU else [])
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, nk=nk, bq=bq, bk=bk,
                          causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda h, i, j, G=G: (h // G, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda h, i, j, G=G: (h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, Sp, hd), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(q, k, v)
    return out[:, :S] if Sp != S else out


def flash_attention_bshd(q, k, v, *, causal=True, interpret=False,
                         **blocks):
    """Batched convenience wrapper: q [B, S, H, hd], k/v [B, T, KV, hd]
    -> [B, S, H, hd] (vmap over batch)."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    fn = functools.partial(flash_attention, causal=causal,
                           interpret=interpret, **blocks)
    out = jax.vmap(fn)(qt, kt, vt)
    return jnp.swapaxes(out, 1, 2)
