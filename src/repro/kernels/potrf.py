"""Leaf Cholesky (POTRF) Pallas kernel.

The tree recursion bottoms out on a b x b SPD tile (b <= 512) that fits in
VMEM. Inside the kernel we run a blocked right-looking Cholesky over
128-wide panels (MXU-aligned):

    for each 128-panel j (python-unrolled, shapes static):
        L_jj, L_jj^-1  <- vectorised Cholesky + forward substitution
                           (fori_loop over 128 columns, VPU rank-1 updates)
        panel          <- A[below, j] @ L_jj^-T           (MXU)
        trailing       <- trailing - panel @ panel^T      (MXU)

This replaces the paper's cuSOLVER leaf: on TPUs the in-VMEM panel
factorisation keeps the MXU busy on the trailing updates while the 128x128
diagonal factorisation runs on the VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MICRO = 128  # diagonal micro-panel, matches MXU/VREG lane width


def _chol_micro(a):
    """Vectorised unblocked Cholesky of a (m, m) tile; returns lower L."""
    m = a.shape[0]

    def body(j, a):
        piv = jax.lax.dynamic_slice(a, (j, j), (1, 1))
        d = jnp.sqrt(piv)
        col = jax.lax.dynamic_slice_in_dim(a, j, 1, axis=1)  # (m, 1)
        rows = jax.lax.broadcasted_iota(jnp.int32, (m, 1), 0)
        col = jnp.where(rows >= j, col / d, 0.0)
        a = jax.lax.dynamic_update_slice_in_dim(a, col, j, axis=1)
        cols = jax.lax.broadcasted_iota(jnp.int32, (1, m), 1)
        upd = jnp.where(cols > j, col * col.reshape(1, m), 0.0)
        return a - upd

    a = jax.lax.fori_loop(0, m, body, a)
    rows = jax.lax.broadcasted_iota(jnp.int32, (m, m), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (m, m), 1)
    return jnp.where(rows >= cols, a, 0.0)


def _tri_inv_micro(l):
    """X = L^-1 for lower-triangular (m, m) via row-wise forward subst."""
    m = l.shape[0]
    x0 = jnp.zeros_like(l)

    def body(i, x):
        li = jax.lax.dynamic_slice_in_dim(l, i, 1, axis=0)      # (1, m)
        cols = jax.lax.broadcasted_iota(jnp.int32, (1, m), 1)
        li_strict = jnp.where(cols < i, li, 0.0)
        s = jnp.dot(li_strict, x, preferred_element_type=jnp.float32)
        e = (cols == i).astype(l.dtype)
        lii = jax.lax.dynamic_slice(l, (i, i), (1, 1))
        row = (e - s.astype(l.dtype)) / lii
        return jax.lax.dynamic_update_slice_in_dim(x, row, i, axis=0)

    return jax.lax.fori_loop(0, m, body, x0)


def _dus(a, val, i0, j0):
    """Static-offset block write (jnp's .at[slice].set creates an empty
    index constant inside pallas kernels; DUS does not)."""
    return jax.lax.dynamic_update_slice(a, val, (i0, j0))


def _potrf_kernel(a_ref, o_ref, *, b):
    a = a_ref[...].astype(jnp.float32)
    nb = b // MICRO
    for j in range(nb):  # python-unrolled: static shapes per panel
        j0 = j * MICRO
        ajj = a[j0:j0 + MICRO, j0:j0 + MICRO]
        l = _chol_micro(ajj)
        a = _dus(a, l, j0, j0)
        if j < nb - 1:
            linv = _tri_inv_micro(l)
            below = a[j0 + MICRO:, j0:j0 + MICRO]
            panel = jnp.dot(below, linv.T, preferred_element_type=jnp.float32)
            a = _dus(a, panel, j0 + MICRO, j0)
            trail = a[j0 + MICRO:, j0 + MICRO:]
            trail = trail - jnp.dot(panel, panel.T,
                                    preferred_element_type=jnp.float32)
            a = _dus(a, trail, j0 + MICRO, j0 + MICRO)
    rows = jax.lax.broadcasted_iota(jnp.int32, (b, b), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (b, b), 1)
    o_ref[...] = jnp.where(rows >= cols, a, 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def potrf_leaf(a, *, interpret=False):
    """Cholesky of a single SPD tile (n multiple of 128, n <= 512)."""
    n = a.shape[-1]
    assert n % MICRO == 0 and a.shape == (n, n), a.shape
    return pl.pallas_call(
        functools.partial(_potrf_kernel, b=n),
        in_specs=[pl.BlockSpec((n, n), lambda: (0, 0))],
        out_specs=pl.BlockSpec((n, n), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), a.dtype),
        interpret=interpret,
    )(a)


def _tri_inv_kernel(l_ref, o_ref, *, b):
    l = l_ref[...].astype(jnp.float32)
    nb = b // MICRO
    # Diagonal micro-inverses, then blocked forward substitution:
    #   X[i,j] = -inv_i @ ( sum_{j<=k<i} L[i,k] X[k,j] )
    invs = []
    for i in range(nb):
        i0 = i * MICRO
        invs.append(_tri_inv_micro(l[i0:i0 + MICRO, i0:i0 + MICRO]))
    x = jnp.zeros((b, b), jnp.float32)
    for j in range(nb):
        j0 = j * MICRO
        x = _dus(x, invs[j], j0, j0)
        for i in range(j + 1, nb):
            i0 = i * MICRO
            s = jnp.zeros((MICRO, MICRO), jnp.float32)
            for k in range(j, i):
                k0 = k * MICRO
                s = s + jnp.dot(l[i0:i0 + MICRO, k0:k0 + MICRO],
                                x[k0:k0 + MICRO, j0:j0 + MICRO],
                                preferred_element_type=jnp.float32)
            x = _dus(x, -jnp.dot(invs[i], s,
                                 preferred_element_type=jnp.float32),
                     i0, j0)
    o_ref[...] = x.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def tri_inv_leaf(l, *, interpret=False):
    """Inverse of a lower-triangular leaf tile (n multiple of 128)."""
    n = l.shape[-1]
    assert n % MICRO == 0 and l.shape == (n, n), l.shape
    return pl.pallas_call(
        functools.partial(_tri_inv_kernel, b=n),
        in_specs=[pl.BlockSpec((n, n), lambda: (0, 0))],
        out_specs=pl.BlockSpec((n, n), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), l.dtype),
        interpret=interpret,
    )(l)
