"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the Pallas kernels are validated against
(tests/test_kernels.py sweeps shapes & dtypes with assert_allclose).
They are also the CPU execution path selected by ops.py when no TPU is
present, so the whole framework runs end-to-end on a laptop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.precision import DTYPES


def _acc_dtype(*xs):
    """f32 accumulation (MXU semantics) unless an operand is f64 — the
    f64 ladder levels exist only on CPU and must not truncate."""
    if any(jnp.dtype(x.dtype) == jnp.float64 for x in xs):
        return jnp.float64
    return jnp.float32


def qgemm_ref(a, b, *, trans_b=False, scale=1.0, c=None, beta=0.0,
              out_dtype=jnp.float32):
    """Mixed-precision GEMM oracle: out = scale * (a @ b[T]) + beta * c.

    ``a``/``b`` arrive already quantized/cast to the low compute dtype;
    the contraction accumulates in f32 (MXU semantics; f64 on the CPU
    f64 ladder), the epilogue applies the dequantization scale and the
    optional accumulator.
    """
    bt = b.T if trans_b else b
    if jnp.issubdtype(a.dtype, jnp.integer):
        # int8 ladder level: exact integer contraction, f32 epilogue
        acc = jnp.dot(a, bt, preferred_element_type=jnp.int32)
        ad = jnp.float32
    else:
        ad = _acc_dtype(a, b, *((c,) if c is not None else ()))
        acc = jnp.dot(a, bt, preferred_element_type=ad)
    out = acc.astype(ad) * jnp.asarray(scale, ad)
    if c is not None:
        out = out + jnp.asarray(beta, ad) * c.astype(ad)
    return out.astype(out_dtype)


def _compute_dtype(dt):
    """LAPACK/XLA factorizations need >= f32; narrow dtypes compute in f32
    and round back (exactly what real hardware leaf kernels do)."""
    return jnp.float32 if jnp.dtype(dt).itemsize < 4 else dt


def potrf_ref(a):
    """Lower Cholesky factor (upper triangle zeroed)."""
    cd = _compute_dtype(a.dtype)
    return jnp.linalg.cholesky(a.astype(cd)).astype(a.dtype)


def tri_inv_ref(l):
    """Inverse of a lower-triangular matrix."""
    cd = _compute_dtype(l.dtype)
    eye = jnp.eye(l.shape[-1], dtype=cd)
    out = jax.scipy.linalg.solve_triangular(l.astype(cd), eye, lower=True)
    return out.astype(l.dtype)


def trsm_ref(b, l, *, side="right", trans=True):
    """Triangular solve oracle.

    side=right, trans=True  : X = B L^{-T}   (the paper's Alg. 2 form)
    side=left,  trans=False : X = L^{-1} B
    side=left,  trans=True  : X = L^{-T} B
    """
    cd = _compute_dtype(b.dtype)
    bc, lc = b.astype(cd), l.astype(cd)
    if side == "right" and trans:
        y = jax.scipy.linalg.solve_triangular(lc, bc.T, lower=True, trans=0)
        return y.T.astype(b.dtype)
    if side == "left" and not trans:
        return jax.scipy.linalg.solve_triangular(
            lc, bc, lower=True, trans=0).astype(b.dtype)
    if side == "left" and trans:
        return jax.scipy.linalg.solve_triangular(
            lc, bc, lower=True, trans=1).astype(b.dtype)
    raise NotImplementedError(f"trsm side={side} trans={trans}")


def residual_ref(a, x, b):
    """IR residual oracle: r = b - a @ x with f32 accumulation (f64 if
    any operand is f64). ``x``/``b`` may be (n,) or (n, k) multi-RHS."""
    ad = _acc_dtype(a, x, b)
    acc = jnp.dot(a, x, preferred_element_type=ad)
    return (b.astype(ad) - acc).astype(b.dtype)


def _round_tiles(x, name, quant, b):
    """Per-(b, b)-tile ``storage_round``, vectorized over an (R, C) block.

    Bitwise-identical per tile to ``repro.core.quantize.storage_round``
    (same reductions, same cast chain) but one fused pass instead of a
    python loop over tiles — the oracle's hot rounding path.
    """
    from repro.core.precision import DTYPES, NARROW, RMAX  # lazy: no cycle
    dt = jnp.dtype(DTYPES[name])
    if dt == x.dtype:
        return x
    R, C = x.shape
    t = x.reshape(R // b, b, C // b, b)
    if name == "int8":
        amax = jnp.max(jnp.abs(t), axis=(1, 3), keepdims=True)
        amax = amax.astype(jnp.float32)
        alpha = jnp.maximum(amax, jnp.float32(1e-30)) / jnp.float32(127.0)
        q = jnp.clip(jnp.round(t.astype(jnp.float32) / alpha), -127, 127)
        return (q * alpha).astype(x.dtype).reshape(R, C)
    if name in NARROW and quant:
        amax = jnp.max(jnp.abs(t), axis=(1, 3), keepdims=True)
        amax = amax.astype(jnp.float32)
        alpha = jnp.maximum(jnp.float32(1.0),
                            amax / jnp.float32(RMAX[name]))
        q = (t / alpha.astype(t.dtype)).astype(dt).astype(x.dtype)
        return (q * alpha.astype(x.dtype)).reshape(R, C)
    return t.astype(dt).astype(x.dtype).reshape(R, C)


def _name_runs(names, quants):
    """Contiguous (start, end, name, quant) runs of equal dtype name."""
    runs, i = [], 0
    while i < len(names):
        i2 = i
        while i2 < len(names) and names[i2] == names[i]:
            i2 += 1
        runs.append((i, i2, names[i], quants[i]))
        i = i2
    return runs


def _pair_rects(pair_names, nt):
    """Decompose the strict-lower pair-dtype map into constant-dtype
    rectangles ``(r0, r1, c0, c1, name)`` by merging equal row-runs
    across adjacent columns — the plan's bisection structure makes the
    coarse levels merge into a handful of large blocks, so the trailing
    update runs as a few big GEMMs instead of one per tile pair."""
    rects, open_ = [], {}
    for j in range(nt + 1):
        runs = set()
        if j < nt:
            i = j + 1
            while i < nt:
                nm = pair_names[i][j]
                i2 = i
                while i2 < nt and pair_names[i2][j] == nm:
                    i2 += 1
                runs.add((i, i2, nm))
                i = i2
        for key in list(open_):
            if key not in runs:
                rects.append((key[0], key[1], open_.pop(key), j, key[2]))
        for key in runs:
            open_.setdefault(key, j)
    return rects


@functools.partial(
    jax.jit,
    static_argnames=("store_names", "store_quants", "pair_names",
                     "pair_quants", "rounding"))
def panel_update_ref(linv, a21, c, *, store_names, store_quants,
                     pair_names, pair_quants, rounding=True):
    """Oracle for the fused panel update (kernels/panel.py).

    Same math as the kernel, tile for tile: per-tile storage rounding of
    the incoming panel, ``L21 = A21 @ L11^-T`` with wide accumulation,
    per-tile storage rounding of L21, then the lower-triangular trailing
    update with both operands rounded to each (i, j) pair's compute
    dtype and the updated partial sums rounded back to tile precision.
    Work is grouped for XLA: panel rows by storage-dtype run, trailing
    pairs by constant-dtype rectangle (:func:`_pair_rects`), rounding by
    fused per-tile passes (:func:`_round_tiles`).
    """
    m, b = a21.shape
    nt = m // b
    assert m % b == 0 and c.shape == (m, m), (a21.shape, c.shape)
    ad = _acc_dtype(linv, a21, c)
    linv_t = linv.T.astype(ad)

    segs = []
    for (i, i2, nm, q) in _name_runs(store_names, store_quants):
        blk = a21[i * b:i2 * b].astype(ad)
        if rounding:
            blk = _round_tiles(blk, nm, q, b)
        li = jnp.dot(blk, linv_t, preferred_element_type=ad)
        if rounding:
            li = _round_tiles(li, nm, q, b)
        segs.append(li)
    l21 = segs[0] if len(segs) == 1 else jnp.concatenate(segs, axis=0)

    quant_by = {nm: q for row_n, row_q in zip(pair_names, pair_quants)
                for nm, q in zip(row_n, row_q)}
    lq = {nm: _round_tiles(l21, nm, q, b) for nm, q in quant_by.items()}

    for (r0, r1, c0, c1, nm) in _pair_rects(pair_names, nt):
        u = jnp.dot(lq[nm][r0 * b:r1 * b], lq[nm][c0 * b:c1 * b].T,
                    preferred_element_type=ad)
        blk = c[r0 * b:r1 * b, c0 * b:c1 * b].astype(ad) - u
        if rounding:
            blk = _round_tiles(blk, nm, quant_by[nm], b)
        c = c.at[r0 * b:r1 * b, c0 * b:c1 * b].set(blk.astype(c.dtype))

    rows = jax.lax.broadcasted_iota(jnp.int32, (b, b), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (b, b), 1)
    for j in range(nt):
        nm = pair_names[j][j]
        lj = lq[nm][j * b:(j + 1) * b]
        j0 = j * b
        cur = c[j0:j0 + b, j0:j0 + b].astype(ad)
        upd = cur - jnp.dot(lj, lj.T, preferred_element_type=ad)
        if rounding:
            upd = _round_tiles(upd, nm, quant_by[nm], b)
        upd = jnp.where(rows >= cols, upd, cur)
        c = c.at[j0:j0 + b, j0:j0 + b].set(upd.astype(c.dtype))
    return l21.astype(a21.dtype), c


def syrk_ref(c, a, *, alpha=1.0, beta=1.0, scale=1.0):
    """SYRK oracle: lower(C) <- beta*C + alpha*scale*(A A^T); upper kept.

    ``scale`` carries the dequantization factor when A is quantized.
    """
    if jnp.issubdtype(a.dtype, jnp.integer):
        a = a.astype(DTYPES["bf16"])      # exact for int8 (|v| <= 127)
    ad = _acc_dtype(c, a)
    acc = jnp.dot(a, a.T, preferred_element_type=ad)
    upd = (jnp.asarray(beta, ad) * c.astype(ad)
           + jnp.asarray(alpha, ad) * jnp.asarray(scale, ad) * acc)
    n = c.shape[-1]
    row = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    return jnp.where(row >= col, upd, c.astype(ad)).astype(c.dtype)
