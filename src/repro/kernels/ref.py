"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the Pallas kernels are validated against
(tests/test_kernels.py sweeps shapes & dtypes with assert_allclose).
They are also the CPU execution path selected by ops.py when no TPU is
present, so the whole framework runs end-to-end on a laptop.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _acc_dtype(*xs):
    """f32 accumulation (MXU semantics) unless an operand is f64 — the
    f64 ladder levels exist only on CPU and must not truncate."""
    if any(jnp.dtype(x.dtype) == jnp.float64 for x in xs):
        return jnp.float64
    return jnp.float32


def qgemm_ref(a, b, *, trans_b=False, scale=1.0, c=None, beta=0.0,
              out_dtype=jnp.float32):
    """Mixed-precision GEMM oracle: out = scale * (a @ b[T]) + beta * c.

    ``a``/``b`` arrive already quantized/cast to the low compute dtype;
    the contraction accumulates in f32 (MXU semantics; f64 on the CPU
    f64 ladder), the epilogue applies the dequantization scale and the
    optional accumulator.
    """
    bt = b.T if trans_b else b
    if jnp.issubdtype(a.dtype, jnp.integer):
        # int8 ladder level: exact integer contraction, f32 epilogue
        acc = jnp.dot(a, bt, preferred_element_type=jnp.int32)
        ad = jnp.float32
    else:
        ad = _acc_dtype(a, b, *((c,) if c is not None else ()))
        acc = jnp.dot(a, bt, preferred_element_type=ad)
    out = acc.astype(ad) * jnp.asarray(scale, ad)
    if c is not None:
        out = out + jnp.asarray(beta, ad) * c.astype(ad)
    return out.astype(out_dtype)


def _compute_dtype(dt):
    """LAPACK/XLA factorizations need >= f32; narrow dtypes compute in f32
    and round back (exactly what real hardware leaf kernels do)."""
    return jnp.float32 if jnp.dtype(dt).itemsize < 4 else dt


def potrf_ref(a):
    """Lower Cholesky factor (upper triangle zeroed)."""
    cd = _compute_dtype(a.dtype)
    return jnp.linalg.cholesky(a.astype(cd)).astype(a.dtype)


def tri_inv_ref(l):
    """Inverse of a lower-triangular matrix."""
    cd = _compute_dtype(l.dtype)
    eye = jnp.eye(l.shape[-1], dtype=cd)
    out = jax.scipy.linalg.solve_triangular(l.astype(cd), eye, lower=True)
    return out.astype(l.dtype)


def trsm_ref(b, l, *, side="right", trans=True):
    """Triangular solve oracle.

    side=right, trans=True  : X = B L^{-T}   (the paper's Alg. 2 form)
    side=left,  trans=False : X = L^{-1} B
    side=left,  trans=True  : X = L^{-T} B
    """
    cd = _compute_dtype(b.dtype)
    bc, lc = b.astype(cd), l.astype(cd)
    if side == "right" and trans:
        y = jax.scipy.linalg.solve_triangular(lc, bc.T, lower=True, trans=0)
        return y.T.astype(b.dtype)
    if side == "left" and not trans:
        return jax.scipy.linalg.solve_triangular(
            lc, bc, lower=True, trans=0).astype(b.dtype)
    if side == "left" and trans:
        return jax.scipy.linalg.solve_triangular(
            lc, bc, lower=True, trans=1).astype(b.dtype)
    raise NotImplementedError(f"trsm side={side} trans={trans}")


def residual_ref(a, x, b):
    """IR residual oracle: r = b - a @ x with f32 accumulation (f64 if
    any operand is f64). ``x``/``b`` may be (n,) or (n, k) multi-RHS."""
    ad = _acc_dtype(a, x, b)
    acc = jnp.dot(a, x, preferred_element_type=ad)
    return (b.astype(ad) - acc).astype(b.dtype)


def syrk_ref(c, a, *, alpha=1.0, beta=1.0, scale=1.0):
    """SYRK oracle: lower(C) <- beta*C + alpha*scale*(A A^T); upper kept.

    ``scale`` carries the dequantization factor when A is quantized.
    """
    if jnp.issubdtype(a.dtype, jnp.integer):
        a = a.astype(jnp.bfloat16)      # exact for int8 (|v| <= 127)
    ad = _acc_dtype(c, a)
    acc = jnp.dot(a, a.T, preferred_element_type=ad)
    upd = (jnp.asarray(beta, ad) * c.astype(ad)
           + jnp.asarray(alpha, ad) * jnp.asarray(scale, ad) * acc)
    n = c.shape[-1]
    row = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    return jnp.where(row >= col, upd, c.astype(ad)).astype(c.dtype)
