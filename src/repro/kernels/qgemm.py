"""Quantized mixed-precision GEMM Pallas kernel (the framework's hot loop).

TPU mapping of the paper's FP16 tensor-core GEMMs:
  * inputs arrive in the low compute dtype (bf16 native on MXU, f16 for
    paper-faithful quantized mode),
  * contraction runs on the MXU with f32 accumulation in a VMEM scratch
    accumulator,
  * the dequantization scale (alpha * scale_a * scale_b) and the optional
    ``beta * C`` accumuland are fused into the epilogue on the last k-step.

Grid is (M/bm, N/bn, K/bk) with k innermost ("arbitrary") so the VMEM
accumulator carries across k-steps; m/n are parallel dimensions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.precision import DTYPES

try:  # TPU-specific bits are optional so interpret mode works anywhere.
    from jax.experimental.pallas import tpu as pltpu
    _HAS_PLTPU = True
except ImportError:  # pragma: no cover
    pltpu = None
    _HAS_PLTPU = False

# Default tile sizes: MXU-aligned (multiples of 128), working set
# 2*(bm*bk + bk*bn)*2B + bm*bn*4B ~ 1.3 MB << 16 MB VMEM.
DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 512


def _kernel(s_ref, a_ref, b_ref, o_ref, acc_ref, *, trans_b, nk, has_c,
            c_ref=None):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    if trans_b:
        b = b.T
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        scale = s_ref[0, 0]
        out = acc_ref[...] * scale
        if has_c:
            beta = s_ref[1, 0]
            out = out + beta * c_ref[...].astype(jnp.float32)
        o_ref[...] = out.astype(o_ref.dtype)


def _kernel_with_c(s_ref, a_ref, b_ref, c_ref, o_ref, acc_ref, *, trans_b, nk):
    _kernel(s_ref, a_ref, b_ref, o_ref, acc_ref, trans_b=trans_b, nk=nk,
            has_c=True, c_ref=c_ref)


def _compiler_params():
    if not _HAS_PLTPU:
        return None
    cls = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams", None)
    if cls is None:
        return None
    try:
        return cls(dimension_semantics=("parallel", "parallel", "arbitrary"))
    except TypeError:  # pragma: no cover - API drift guard
        return None


@functools.partial(
    jax.jit,
    static_argnames=("trans_b", "out_dtype", "bm", "bn", "bk", "interpret"))
def qgemm(a, b, scale, *, c=None, beta=0.0, trans_b=False,
          out_dtype=jnp.float32, bm=DEFAULT_BM, bn=DEFAULT_BN,
          bk=DEFAULT_BK, interpret=False):
    """out = scale * (a @ b[.T]) [+ beta * c], f32 accumulation.

    a: (M, K) low precision.  b: (K, N) or (N, K) when trans_b.
    scale: scalar f32 dequantization factor (already includes alpha).
    c: optional (M, N) accumuland in any float dtype.
    """
    M, K = a.shape
    N = b.shape[0] if trans_b else b.shape[1]
    kb = b.shape[1] if trans_b else b.shape[0]
    assert kb == K, (a.shape, b.shape, trans_b)

    # int8 ladder level: values in [-127, 127] are exact in bf16 and the
    # f32 accumulator is exact up to k*127^2 < 2^24, so the bf16 MXU path
    # is bit-identical to int32 accumulation at our tile sizes. A native
    # s8 MXU kernel (2x rate on v5e) is the on-hardware upgrade path.
    if jnp.issubdtype(a.dtype, jnp.integer):
        a = a.astype(DTYPES["bf16"])
    if jnp.issubdtype(b.dtype, jnp.integer):
        b = b.astype(DTYPES["bf16"])

    bm = min(bm, M)
    bn = min(bn, N)
    bk = min(bk, K)
    # Pad to tile multiples; zero padding is exact for matmul.
    Mp, Np, Kp = (-(-M // bm)) * bm, (-(-N // bn)) * bn, (-(-K // bk)) * bk
    if (Mp, Kp) != (M, K):
        a = jnp.pad(a, ((0, Mp - M), (0, Kp - K)))
    if trans_b:
        if (Np, Kp) != b.shape:
            b = jnp.pad(b, ((0, Np - N), (0, Kp - K)))
    else:
        if (Kp, Np) != b.shape:
            b = jnp.pad(b, ((0, Kp - K), (0, Np - N)))
    has_c = c is not None
    if has_c and (Mp, Np) != c.shape:
        c = jnp.pad(c, ((0, Mp - M), (0, Np - N)))

    nk = Kp // bk
    grid = (Mp // bm, Np // bn, nk)

    s = jnp.stack([jnp.asarray(scale, jnp.float32),
                   jnp.asarray(beta, jnp.float32)]).reshape(2, 1)

    b_spec = (pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)) if trans_b
              else pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)))
    in_specs = [
        pl.BlockSpec((2, 1), lambda i, j, k: (0, 0)),
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        b_spec,
    ]
    operands = [s, a, b]
    if has_c:
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)))
        operands.append(c)
        kernel = functools.partial(_kernel_with_c, trans_b=trans_b, nk=nk)
    else:
        kernel = functools.partial(_kernel, trans_b=trans_b, nk=nk,
                                   has_c=False)

    scratch = ([pltpu.VMEM((bm, bn), jnp.float32)] if _HAS_PLTPU
               else [pl.MemorySpace.ANY((bm, bn), jnp.float32)])  # pragma: no cover

    params = {}
    cp = _compiler_params()
    if cp is not None and not interpret:
        params["compiler_params"] = cp

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **params,
    )(*operands)
    if (Mp, Np) != (M, N):
        out = out[:M, :N]
    return out
