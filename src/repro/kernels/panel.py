"""Fused panel-update Pallas kernel for the flat blocked Cholesky.

For each factored leaf panel the blocked executor (core/blocked.py) must
apply

    L21 = A21 @ L11^{-T}          (panel TRSM, leaf inverse precomputed)
    A22 -= L21 @ L21^T            (trailing SYRK, lower tiles only)

The tree dispatches these as a trsm call plus a syrk call per recursion
node; this kernel fuses both into ONE gridded ``pallas_call`` per panel:
the grid enumerates only the ``nt(nt+1)/2`` lower trailing tiles (reusing
:func:`repro.kernels.syrk._tri_decode`'s triangular index decode), each
program recomputes its row/column L21 tiles from VMEM-resident ``L11^-1``
(an extra rank-``b`` GEMM per tile — cheap on the MXU next to the tile
update, and it removes the inter-kernel HBM round-trip for L21), applies
the update with f32 accumulation, and the per-tile storage rounding /
quantization (the plan's dtype assignment) runs in the epilogue. The
``(i, 0..i)`` programs for one row are consecutive, so the L21 output
block stays VMEM-resident and is written once per row tile.

Per-tile precision metadata arrives as *static* tuples (the plan is pure
geometry); the rounding variants are compiled in, and two tiny int32
code tables (per-row storage dtype, per-pair compute dtype) ride along
as VMEM inputs read with masked-iota lookups. f64 containers route to
the jnp oracle in ops.py (the MXU has no f64 path), exactly like the
residual kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.precision import DTYPES, RMAX
from repro.kernels.syrk import _tri_decode


def _round_name(x, name: str, quant: bool):
    """Round f32 VALUES onto ``name``'s storage grid (keeps f32).

    Mirrors ``repro.core.quantize.storage_round`` op-for-op so the
    kernel and the jnp oracle agree bitwise; inlined here (rather than
    imported) because the quantized paths must stay Pallas-traceable.
    """
    if name in ("f32", "f64"):
        # f64 CONTAINERS route to the jnp oracle in ops.py; an f64 level
        # NAME on the f32 container this kernel runs on is the identity
        return x
    if name == "bf16":
        return x.astype(DTYPES["bf16"]).astype(jnp.float32)
    if name == "int8":
        amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
        alpha = jnp.maximum(amax, jnp.float32(1e-30)) / jnp.float32(127.0)
        q = jnp.clip(jnp.round(x / alpha), -127.0, 127.0)
        return q * alpha
    assert name == "f16", name
    if not quant:
        return x.astype(DTYPES["f16"]).astype(jnp.float32)
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    alpha = jnp.maximum(jnp.float32(1.0), amax / jnp.float32(RMAX["f16"]))
    q = (x / alpha).astype(DTYPES["f16"]).astype(jnp.float32)
    return q * alpha


def _round_select(x, code, names, quants):
    """Apply the rounding variant selected by the traced scalar ``code``
    (an index into the static ``names`` tuple)."""
    out = _round_name(x, names[0], quants[0])
    for k in range(1, len(names)):
        out = jnp.where(code == k, _round_name(x, names[k], quants[k]), out)
    return out


def _code_lookup(arr, *idx):
    """Masked-iota gather of a (VMEM-resident) int32 code table by traced
    indices — dynamic scalar indexing without SMEM plumbing."""
    mask = jnp.ones(arr.shape, bool)
    for d, ix in enumerate(idx):
        iota = jax.lax.broadcasted_iota(jnp.int32, arr.shape, d)
        mask = mask & (iota == ix)
    return jnp.sum(jnp.where(mask, arr, 0))


def _panel_kernel(sc_ref, pc_ref, linv_ref, ai_ref, aj_ref, c_ref,
                  l21_ref, co_ref, *, names, quants, rounding, b):
    t = pl.program_id(0)
    i, j = _tri_decode(t)
    store_codes = sc_ref[...]
    pair_codes = pc_ref[...]
    linv_t = linv_ref[...].astype(jnp.float32).T

    def solve_tile(a_tile, row):
        code = _code_lookup(store_codes, row)
        a = a_tile.astype(jnp.float32)
        if rounding:
            a = _round_select(a, code, names, quants)
        lt = jnp.dot(a, linv_t, preferred_element_type=jnp.float32)
        if rounding:
            lt = _round_select(lt, code, names, quants)
        return lt

    li = solve_tile(ai_ref[...], i)
    l21_ref[...] = li.astype(l21_ref.dtype)

    # trailing update at the (i, j) pair's compute precision
    pc = _code_lookup(pair_codes, i, j)
    qi = _round_select(li, pc, names, quants)
    lj = solve_tile(aj_ref[...], j)
    qj = _round_select(lj, pc, names, quants)
    upd = (c_ref[...].astype(jnp.float32)
           - jnp.dot(qi, qj.T, preferred_element_type=jnp.float32))
    if rounding:
        # the trailing matrix LIVES at its tiles' precision between
        # panels (paper Fig. 3) — round the updated partial sum back
        upd = _round_select(upd, pc, names, quants)
    rows = jax.lax.broadcasted_iota(jnp.int32, (b, b), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (b, b), 1)
    keep = jnp.logical_or(i != j, rows >= cols)
    co_ref[...] = jnp.where(keep, upd,
                            c_ref[...].astype(jnp.float32)).astype(co_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("store_names", "store_quants", "pair_names",
                     "pair_quants", "rounding", "interpret"))
def panel_update(linv, a21, c, *, store_names, store_quants, pair_names,
                 pair_quants, rounding=True, interpret=False):
    """Fused panel TRSM + trailing SYRK update.

    ``linv``: (b, b) inverse of the factored diagonal leaf; ``a21``:
    (m, b) sub-diagonal panel; ``c``: (m, m) trailing matrix (lower
    triangle meaningful, upper returned untouched). ``store_names`` /
    ``store_quants`` give each trailing row tile's storage dtype;
    ``pair_names``/``pair_quants`` give the compute dtype of every
    trailing (i, j) tile pair — all static, straight out of
    ``PrecisionPlan.panel_meta``. Returns ``(l21, c_updated)``.
    """
    m, b = a21.shape
    assert linv.shape == (b, b), (linv.shape, a21.shape)
    assert c.shape == (m, m), (c.shape, m)
    assert m % b == 0, (m, b)
    nt = m // b
    assert len(store_names) == nt and len(pair_names) == nt
    names = tuple(sorted({*store_names,
                          *(nm for row in pair_names for nm in row)}))
    quant_by = {}
    for nm, q in zip(store_names, store_quants):
        quant_by[nm] = q
    for row_n, row_q in zip(pair_names, pair_quants):
        for nm, q in zip(row_n, row_q):
            assert quant_by.setdefault(nm, q) == q, nm
    quants = tuple(quant_by[nm] for nm in names)
    store_codes = jnp.asarray([names.index(nm) for nm in store_names],
                              jnp.int32).reshape(nt, 1)
    pair_codes = jnp.asarray([[names.index(nm) for nm in row]
                              for row in pair_names], jnp.int32)
    ntri = nt * (nt + 1) // 2

    def ai_map(t):
        i, _ = _tri_decode(t)
        return (i, 0)

    def aj_map(t):
        _, j = _tri_decode(t)
        return (j, 0)

    def c_map(t):
        return _tri_decode(t)

    l21, c_out = pl.pallas_call(
        functools.partial(_panel_kernel, names=names, quants=quants,
                          rounding=rounding, b=b),
        grid=(ntri,),
        in_specs=[
            pl.BlockSpec((nt, 1), lambda t: (0, 0)),
            pl.BlockSpec((nt, nt), lambda t: (0, 0)),
            pl.BlockSpec((b, b), lambda t: (0, 0)),
            pl.BlockSpec((b, b), ai_map),
            pl.BlockSpec((b, b), aj_map),
            pl.BlockSpec((b, b), c_map),
        ],
        out_specs=[
            pl.BlockSpec((b, b), ai_map),
            pl.BlockSpec((b, b), c_map),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, b), a21.dtype),
            jax.ShapeDtypeStruct((m, m), c.dtype),
        ],
        interpret=interpret,
    )(store_codes, pair_codes, linv, a21, a21, c)
    # Upper trailing tiles were never visited; restore them from the
    # input so callers see an intact upper triangle (syrk_packed idiom).
    rows = jax.lax.broadcasted_iota(jnp.int32, (m, m), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (m, m), 1)
    touched = (rows // b) >= (cols // b)
    return l21, jnp.where(touched, c_out, c.astype(c_out.dtype))
