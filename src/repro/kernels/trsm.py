"""Leaf TRSM Pallas kernel: X = B @ L^{-T} for a leaf-sized L.

TPU adaptation (documented in docs/ARCHITECTURE.md, "Leaf kernels"): instead of per-column
substitution (latency-bound on a systolic array), we invert the leaf
triangle once in VMEM (kernels/potrf.py:tri_inv_leaf) and turn the solve
into a GEMM, which is exactly what the MXU wants. The row dimension of B
is gridded so arbitrarily tall panels stream through VMEM while L^{-1}
stays resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.potrf import tri_inv_leaf

DEFAULT_BM = 512


def _trsm_kernel(b_ref, linv_ref, o_ref, *, trans):
    b = b_ref[...]
    linv = linv_ref[...]
    if trans:
        linv = linv.T
    o_ref[...] = jnp.dot(b, linv,
                         preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def trsm_leaf(b, l=None, *, linv=None, bm=DEFAULT_BM, interpret=False):
    """Solve X L^T = B (right, lower, transposed — the paper's Alg. 2 leaf).

    b: (M, n) panel; l: (n, n) lower-triangular leaf (n multiple of 128).
    ``linv`` takes a precomputed ``tri_inv_leaf(l)`` so repeated solves
    against one factor (cholesky_solve's two sweeps, K-FAC steps, the
    serve factor cache) skip the O(n^3) leaf inversion; otherwise it is
    computed here from ``l``.
    """
    M, n = b.shape
    if linv is None:
        assert l is not None and l.shape == (n, n), (b.shape,)
        linv = tri_inv_leaf(l, interpret=interpret)
    assert linv.shape == (n, n), (linv.shape, b.shape)

    bm = min(bm, M)
    Mp = (-(-M // bm)) * bm
    if Mp != M:
        b = jnp.pad(b, ((0, Mp - M), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_trsm_kernel, trans=True),
        grid=(Mp // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Mp, n), b.dtype),
        interpret=interpret,
    )(b, linv.astype(b.dtype))
    return out[:M] if Mp != M else out
