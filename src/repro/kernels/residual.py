"""Fused residual kernel: r = b - A @ x, the IR hot path.

Every iterative-refinement sweep forms the residual in the residual
precision — an O(n^2) GEMM that the mixed-precision literature says
should be nearly free next to the O(n^3) factorization, but which
dominates serve-side sweep latency when left to generic XLA (separate
matmul + subtract, two HBM round-trips for the intermediate).
``residual_fused`` tiles the GEMM over (row-block, k-block) grid cells,
accumulates A @ x in an f32 VMEM scratch, and fuses the ``b - acc``
epilogue into the final k-step so the intermediate product never touches
HBM.

``ref.residual_ref`` is the pure-jnp oracle (and the CPU execution
path); ``ops.residual`` dispatches between them. f64 residuals (the x64
accuracy ladder) always take the reference path — the TPU MXU has no
f64, and the fused kernel's f32 accumulator would silently truncate.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _HAS_PLTPU = True
except ImportError:  # pragma: no cover
    pltpu = None
    _HAS_PLTPU = False

from repro.kernels import ref as _ref

DEFAULT_BM = 256
DEFAULT_BK = 512
#: TPU lane width — RHS column counts are padded up to a multiple of this
LANE = 128


def _residual_kernel(a_ref, x_ref, b_ref, o_ref, acc_ref, *, nk):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], x_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        o_ref[...] = (b_ref[...].astype(jnp.float32)
                      - acc_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "interpret"))
def residual_fused(a, x, b, *, bm=DEFAULT_BM, bk=DEFAULT_BK,
                   interpret=False):
    """Fused r = b - a @ x. a: (n, n); x, b: (n,) or (n, k).

    Grid = (n/bm, n/bk); each row-block accumulates its k-panels in an
    f32 VMEM scratch and subtracts from b in the epilogue. Inputs are
    zero-padded to tile/lane multiples and the result sliced back, so
    arbitrary n and k are accepted.
    """
    if not _HAS_PLTPU:  # pragma: no cover — the k-accumulation needs
        return _ref.residual_ref(a, x, b)  # the VMEM scratch to exist
    vec = x.ndim == 1
    if vec:
        x, b = x[:, None], b[:, None]
    n, kc = x.shape
    assert a.shape == (n, n) and b.shape == (n, kc), (a.shape, b.shape)
    bm, bk = min(bm, n), min(bk, n)
    npad = -(-n // bm) * bm          # row blocking of A / b / r
    kpad = -(-n // bk) * bk          # contraction blocking of A / x
    cpad = -(-kc // LANE) * LANE
    if (npad, kpad) != (n, n):
        a = jnp.pad(a, ((0, npad - n), (0, kpad - n)))
    if kpad != n:
        x = jnp.pad(x, ((0, kpad - n), (0, 0)))
    if npad != n:
        b = jnp.pad(b, ((0, npad - n), (0, 0)))
    if cpad != kc:
        x = jnp.pad(x, ((0, 0), (0, cpad - kc)))
        b = jnp.pad(b, ((0, 0), (0, cpad - kc)))
    nm, nk = npad // bm, kpad // bk
    scratch = [pltpu.VMEM((bm, cpad), jnp.float32)]
    out = pl.pallas_call(
        functools.partial(_residual_kernel, nk=nk),
        grid=(nm, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            pl.BlockSpec((bk, cpad), lambda i, k: (k, 0)),
            pl.BlockSpec((bm, cpad), lambda i, k: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, cpad), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((npad, cpad), b.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(a, x, b)
    out = out[:n, :kc]
    return out[:, 0] if vec else out
