"""SYRK Pallas kernels: C <- beta*C + alpha*scale*(A A^T), lower triangle.

Two kernels:

* ``syrk_leaf`` — the tree recursion's diagonal leaf: a single (b, b)
  output tile with the k-dimension gridded (A panels can be very wide),
  f32 VMEM accumulator, diagonal masking fused in the epilogue.

* ``syrk_packed`` — beyond-paper fused SYRK for *large* n: instead of
  recursing (paper) or running a rectangular grid and discarding the upper
  half (2x waste), the grid enumerates only the n_t(n_t+1)/2 lower tiles;
  the (i, j) tile coordinates are decoded from the linear triangular index
  inside the index_map. This is the flat-kernel rival we hillclimb against
  tree-SYRK in benchmarks/bench_syrk.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.precision import DTYPES

try:
    from jax.experimental.pallas import tpu as pltpu
    _HAS_PLTPU = True
except ImportError:  # pragma: no cover
    pltpu = None
    _HAS_PLTPU = False

DEFAULT_BN = 256
DEFAULT_BK = 512


def _mask_lower(tile, i_blk, j_blk, bn):
    """Zero the strictly-upper part of a diagonal tile (i_blk == j_blk)."""
    rows = jax.lax.broadcasted_iota(jnp.int32, (bn, bn), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (bn, bn), 1)
    on_diag = i_blk == j_blk
    keep = jnp.logical_or(jnp.logical_not(on_diag), rows >= cols)
    return jnp.where(keep, tile, 0.0)


def _syrk_leaf_kernel(s_ref, a_ref, c_ref, o_ref, acc_ref, *, nk):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    acc_ref[...] += jnp.dot(a, a.T, preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        scale, beta = s_ref[0, 0], s_ref[1, 0]
        c = c_ref[...].astype(jnp.float32)
        upd = beta * c + scale * acc_ref[...]
        n = upd.shape[0]
        rows = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
        o_ref[...] = jnp.where(rows >= cols, upd, c).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def syrk_leaf(c, a, scale, beta, *, bk=DEFAULT_BK, interpret=False):
    """Diagonal-leaf SYRK: c (n,n) f32-ish, a (n,K) low precision."""
    n, K = a.shape
    assert c.shape == (n, n)
    if jnp.issubdtype(a.dtype, jnp.integer):
        a = a.astype(DTYPES["bf16"])      # exact for int8 (|v| <= 127)
    bk = min(bk, K)
    Kp = (-(-K // bk)) * bk
    if Kp != K:
        a = jnp.pad(a, ((0, 0), (0, Kp - K)))
    nk = Kp // bk
    s = jnp.stack([jnp.asarray(scale, jnp.float32),
                   jnp.asarray(beta, jnp.float32)]).reshape(2, 1)
    scratch = ([pltpu.VMEM((n, n), jnp.float32)] if _HAS_PLTPU else [])
    return pl.pallas_call(
        functools.partial(_syrk_leaf_kernel, nk=nk),
        grid=(nk,),
        in_specs=[
            pl.BlockSpec((2, 1), lambda k: (0, 0)),
            pl.BlockSpec((n, bk), lambda k: (0, k)),
            pl.BlockSpec((n, n), lambda k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n, n), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), c.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(s, a, c)


def _tri_decode(t):
    """Decode linear lower-triangular index t -> (i, j), i >= j.

    i = floor((sqrt(8t+1)-1)/2) computed in f32 with a +-1 integer
    correction (exact for the grid sizes we use, t < 2^20).
    """
    tf = t.astype(jnp.float32)
    i0 = jnp.floor((jnp.sqrt(8.0 * tf + 1.0) - 1.0) / 2.0).astype(jnp.int32)
    # correct rounding both ways
    i0 = jnp.where((i0 + 1) * (i0 + 2) // 2 <= t, i0 + 1, i0)
    i0 = jnp.where(i0 * (i0 + 1) // 2 > t, i0 - 1, i0)
    j = t - i0 * (i0 + 1) // 2
    return i0, j


def _syrk_packed_kernel(s_ref, a_ref, at_ref, c_ref, o_ref, acc_ref, *, nk,
                        bn):
    k = pl.program_id(1)
    t = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], at_ref[...].T,
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        i_blk, j_blk = _tri_decode(t)
        scale, beta = s_ref[0, 0], s_ref[1, 0]
        c = c_ref[...].astype(jnp.float32)
        upd = beta * c + scale * acc_ref[...]
        rows = jax.lax.broadcasted_iota(jnp.int32, (bn, bn), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (bn, bn), 1)
        keep = jnp.logical_or(i_blk != j_blk, rows >= cols)
        o_ref[...] = jnp.where(keep, upd, c).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "bk", "interpret"))
def syrk_packed(c, a, scale, beta, *, bn=DEFAULT_BN, bk=DEFAULT_BK,
                interpret=False):
    """Fused triangular-packed SYRK over the full (n, n) lower triangle.

    Grid = (n_t(n_t+1)/2, K/bk): only lower tiles are enumerated; tile
    coordinates are decoded from the linear index inside the index_maps.
    """
    n, K = a.shape
    assert c.shape == (n, n)
    if jnp.issubdtype(a.dtype, jnp.integer):
        a = a.astype(DTYPES["bf16"])      # exact for int8 (|v| <= 127)
    bn = min(bn, n)
    bk = min(bk, K)
    npad = (-(-n // bn)) * bn
    Kp = (-(-K // bk)) * bk
    if (npad, Kp) != (n, K):
        a = jnp.pad(a, ((0, npad - n), (0, Kp - K)))
    if npad != n:
        c = jnp.pad(c, ((0, npad - n), (0, npad - n)))
    nt = npad // bn
    nk = Kp // bk
    ntri = nt * (nt + 1) // 2
    s = jnp.stack([jnp.asarray(scale, jnp.float32),
                   jnp.asarray(beta, jnp.float32)]).reshape(2, 1)

    def a_map(t, k):
        i, _ = _tri_decode(t)
        return (i, k)

    def at_map(t, k):
        _, j = _tri_decode(t)
        return (j, k)

    def c_map(t, k):
        i, j = _tri_decode(t)
        return (i, j)

    scratch = ([pltpu.VMEM((bn, bn), jnp.float32)] if _HAS_PLTPU else [])
    out = pl.pallas_call(
        functools.partial(_syrk_packed_kernel, nk=nk, bn=bn),
        grid=(ntri, nk),
        in_specs=[
            pl.BlockSpec((2, 1), lambda t, k: (0, 0)),
            pl.BlockSpec((bn, bk), a_map),
            pl.BlockSpec((bn, bk), at_map),
            pl.BlockSpec((bn, bn), c_map),
        ],
        out_specs=pl.BlockSpec((bn, bn), c_map),
        out_shape=jax.ShapeDtypeStruct((npad, npad), c.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(s, a, a, c)
    # Off-triangle tiles of the padded output were never visited; restore
    # them from the input so callers see an intact upper triangle.
    rows = jax.lax.broadcasted_iota(jnp.int32, (npad, npad), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (npad, npad), 1)
    tile_touched = (rows // bn) >= (cols // bn)
    out = jnp.where(tile_touched, out, c.astype(out.dtype))
    return out[:n, :n] if npad != n else out
