"""Pallas TPU kernels for the hot compute paths + pure-jnp oracles.

Modules:
  qgemm.py  — quantized mixed-precision GEMM (fused dequant epilogue)
  potrf.py  — leaf Cholesky + leaf triangular inverse (in-VMEM blocked)
  trsm.py   — leaf triangular solve (inverse-then-GEMM, MXU friendly)
  syrk.py   — leaf SYRK + beyond-paper triangular-packed fused SYRK
  residual.py — fused IR residual r = b - A x (refinement sweep hot path)
  flash.py  — causal GQA flash-attention (online softmax in VMEM)
  ops.py    — public dispatching API (pallas / interpret / jnp)
  ref.py    — pure-jnp oracles (ground truth for tests, CPU exec path)
"""
from repro.kernels import ops, ref  # noqa: F401
