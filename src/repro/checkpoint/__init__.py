from repro.checkpoint.checkpoint import (latest_step, restore,  # noqa: F401
                                         save)
