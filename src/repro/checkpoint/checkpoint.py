"""Sharded, atomic, mesh-elastic checkpointing.

Format: one directory per step (``step_0000042/``) containing
  manifest.json   — pytree structure, leaf shapes/dtypes, step, metadata
  <leaf-path>.npy — one file per pytree leaf (global array)

Properties needed at cluster scale:
  * atomic    — written to ``.tmp-step_X`` then os.rename'd; a crash mid
                save never corrupts the latest checkpoint.
  * async     — save() returns a handle immediately; the serialization
                thread runs while training continues (preemption hook
                calls .wait()).
  * elastic   — leaves are stored as *global* arrays with shape/dtype
                metadata; restore() re-shards onto whatever mesh/sharding
                the new job provides (tests prove 8 -> 4 -> 1 devices).
  * bounded   — keep_last cleans old steps after a successful rename.

Multi-host note: in a >1-process job each host would save only its
addressable shards (leaf files gain a ``.shard-k`` suffix and an index in
the manifest); the single-process container exercises the global-array
path. The manifest format already carries the fields needed for that
(see ``shard_index``).
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

_MANIFEST = "manifest.json"


def _leaf_files(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    entries = []
    for path, leaf in flat:
        name = "__".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        entries.append((name, leaf))
    return entries, treedef


def save(path: str, step: int, tree, *, keep_last: int = 3,
         blocking: bool = False, extra: dict | None = None):
    """Write checkpoint for ``step``; returns a handle with .wait()."""
    os.makedirs(path, exist_ok=True)
    final = os.path.join(path, f"step_{step:08d}")
    tmp = os.path.join(path, f".tmp-step_{step:08d}")
    # materialize on host *before* returning so training can mutate
    entries, _ = _leaf_files(tree)
    host = [(n, np.asarray(jax.device_get(l))) for n, l in entries]

    def _write():
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": [], "shard_index": 0,
                    "shard_count": 1, "extra": extra or {}}
        for name, arr in host:
            np.save(os.path.join(tmp, name + ".npy"), arr)
            manifest["leaves"].append(
                {"name": name, "shape": list(arr.shape),
                 "dtype": str(arr.dtype)})
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _cleanup(path, keep_last)

    if blocking:
        _write()
        t = None
    else:
        t = threading.Thread(target=_write, daemon=True)
        t.start()

    class Handle:
        def wait(self):
            if t is not None:
                t.join()
            return final

    return Handle()


def _cleanup(path: str, keep_last: int):
    steps = sorted(d for d in os.listdir(path) if d.startswith("step_"))
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(path, d), ignore_errors=True)


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(path)
             if d.startswith("step_")
             and os.path.exists(os.path.join(path, d, _MANIFEST))]
    return max(steps) if steps else None


def restore(path: str, template, *, step: int | None = None,
            shardings=None):
    """Restore into the structure of ``template``; ``shardings`` may be a
    matching pytree of NamedSharding (or None leaves) — this is what makes
    resume mesh-elastic: the stored global arrays are simply device_put
    with the *new* sharding."""
    step = step if step is not None else latest_step(path)
    assert step is not None, f"no checkpoint under {path}"
    d = os.path.join(path, f"step_{step:08d}")
    entries, treedef = _leaf_files(template)
    shard_list = (None if shardings is None
                  else treedef.flatten_up_to(shardings))
    leaves = []
    for i, (name, tmpl) in enumerate(entries):
        arr = np.load(os.path.join(d, name + ".npy"))
        assert tuple(arr.shape) == tuple(tmpl.shape), (
            f"{name}: ckpt {arr.shape} vs template {tmpl.shape}")
        sh = shard_list[i] if shard_list is not None else None
        leaves.append(jax.device_put(arr, sh) if sh is not None
                      else jax.numpy.asarray(arr))
    return treedef.unflatten(leaves), step
