from repro.train.step import (TrainConfig, init_state,  # noqa: F401
                              make_train_step, reshape_for_accum)
from repro.train import compress  # noqa: F401
