"""Train step factory: microbatched grad accumulation, AdamW or
TreeNewton (paper-solver) optimizer, metrics.

The returned step function is pure and jit/pjit-friendly:
    state, metrics = step_fn(state, batch)
with batch leaves shaped [accum, B/accum, ...] when accum > 1 (the
pipeline reshapes). Gradient accumulation runs as a lax.scan over
microbatches, which both bounds activation memory and lets XLA overlap
the backward collectives of microbatch i with the compute of i+1.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.common import ModelConfig, NO_SHARD, Sharder
from repro.optim import adamw, kfac


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"             # adamw | tree_newton
    adam: adamw.AdamWConfig = dataclasses.field(
        default_factory=adamw.AdamWConfig)
    tree_newton: kfac.TreeNewtonConfig = dataclasses.field(
        default_factory=kfac.TreeNewtonConfig)
    accum: int = 1


def init_state(rng, cfg: ModelConfig, tcfg: TrainConfig) -> dict[str, Any]:
    params = T.init_params(rng, cfg)
    if tcfg.optimizer == "tree_newton":
        opt = kfac.init(params, tcfg.tree_newton)
    else:
        opt = adamw.init(params, tcfg.adam)
    return {"params": params, "opt": opt, "step": jnp.zeros((), jnp.int32)}


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    sharder: Sharder = NO_SHARD):
    def loss_fn(params, mb):
        return T.loss_fn(params, mb, cfg, sharder)

    def grads_of(params, batch):
        if tcfg.accum == 1:
            (loss, m), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, m, grads
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def micro(carry, mb):
            loss_a, g_a = carry
            (loss, m), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            g_a = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), g_a, g)
            return (loss_a + loss, g_a), m

        (loss_sum, grads), ms = jax.lax.scan(
            micro, (jnp.float32(0.0), zeros), batch)
        inv = 1.0 / tcfg.accum
        grads = jax.tree.map(lambda g: g * inv, grads)
        m = jax.tree.map(lambda x: x[-1], ms)
        return loss_sum * inv, m, grads

    def step_fn(state, batch):
        loss, lm_metrics, grads = grads_of(state["params"], batch)
        if tcfg.optimizer == "tree_newton":
            params, opt, om = kfac.apply(grads, state["opt"],
                                         state["params"], tcfg.tree_newton)
        else:
            params, opt, om = adamw.apply(grads, state["opt"],
                                          state["params"], tcfg.adam)
        metrics = {"loss": loss, **lm_metrics, **om}
        return ({"params": params, "opt": opt, "step": state["step"] + 1},
                metrics)

    return step_fn


def reshape_for_accum(batch, accum: int):
    if accum == 1:
        return batch
    return jax.tree.map(
        lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]),
        batch)
