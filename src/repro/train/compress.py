"""int8 error-feedback gradient all-reduce (shard_map collective).

The paper's per-block quantizer (repro.core.quantize) reused for the
distributed-training side: cross-replica gradient reduction in int8 with
an error-feedback residual, the standard compressed-DDP trick. At pod
scale this is applied on the *inter-pod* stage of a hierarchical
all-reduce where links are slowest (docs/ARCHITECTURE.md, "Model and training integrations").

ef_allreduce_mean is a per-shard function meant to run inside shard_map
over the reduction axis; tests/test_train.py runs a full mini data-
parallel trainer with it on 8 host devices and shows convergence matches
the uncompressed baseline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantize import dequant_int8, quant_int8


def ef_allreduce_mean(grad, residual, axis: str):
    """Compressed mean-all-reduce with error feedback.

    grad, residual: local f32 pytree leaves (same shapes).
    Returns (reduced_grad, new_residual).
    """
    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, scale = quant_int8(g32)
        sent = dequant_int8(q, scale)
        new_r = g32 - sent                       # what int8 couldn't carry
        total = jax.lax.pmean(sent, axis)
        return total, new_r

    flat_g, tdef = jax.tree.flatten(grad)
    flat_r = tdef.flatten_up_to(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return tdef.unflatten([o[0] for o in out]), \
        tdef.unflatten([o[1] for o in out])


def init_residual(params):
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
