"""Deterministic, restart-safe data pipeline.

Two sources:
  * SyntheticLM — tokens drawn from a step-keyed PRNG (zipf-ish marginal
    so losses are not flat); fully deterministic in (seed, step), so a
    job restarted from a checkpoint at step k replays the identical
    stream — the idempotence the fault-tolerance story relies on.
  * MemmapLM — memory-mapped token file (uint16/uint32), random windows
    keyed by (seed, step); per-host sharding by host index.

Both emit {"tokens": [B, S], "labels": [B, S]} numpy batches (labels =
next token). A background-thread Prefetcher overlaps host data prep with
device compute (double buffering).
"""
from __future__ import annotations

import queue
import threading

import numpy as np


class SyntheticLM:
    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0,
                 n_codebooks: int = 0, n_img_tokens: int = 0,
                 d_model: int = 0):
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.seed = seed
        self.n_codebooks = n_codebooks
        self.n_img_tokens = n_img_tokens
        self.d_model = d_model

    def get(self, step: int, *, host_index: int = 0, host_count: int = 1):
        b = self.batch // host_count
        rng = np.random.default_rng((self.seed, step, host_index))
        shape = ((b, self.seq + 1, self.n_codebooks) if self.n_codebooks
                 else (b, self.seq + 1))
        # zipf-flavoured marginal clipped to the vocab
        toks = rng.zipf(1.3, size=shape) % self.vocab
        toks = toks.astype(np.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.n_img_tokens:
            out["patch_embeds"] = rng.standard_normal(
                (b, self.n_img_tokens, self.d_model)).astype(np.float32)
        return out


class MemmapLM:
    def __init__(self, path: str, batch: int, seq: int, seed: int = 0,
                 dtype=np.uint16):
        self.data = np.memmap(path, dtype=dtype, mode="r")
        self.batch, self.seq, self.seed = batch, seq, seed

    def get(self, step: int, *, host_index: int = 0, host_count: int = 1):
        b = self.batch // host_count
        rng = np.random.default_rng((self.seed, step, host_index))
        hi = len(self.data) - self.seq - 1
        starts = rng.integers(0, hi, size=b)
        win = np.stack([self.data[s:s + self.seq + 1] for s in starts])
        win = win.astype(np.int32)
        return {"tokens": win[:, :-1], "labels": win[:, 1:]}


class Prefetcher:
    """Double-buffered background prefetch keyed by step counter."""

    def __init__(self, source, start_step: int = 0, depth: int = 2,
                 **kw):
        self.source = source
        self.kw = kw
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self.t = threading.Thread(target=self._work, daemon=True)
        self.t.start()

    def _work(self):
        s = self.step
        while not self._stop.is_set():
            item = (s, self.source.get(s, **self.kw))
            while not self._stop.is_set():
                try:
                    self.q.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue
            s += 1

    def next(self):
        step, batch = self.q.get()
        return step, batch

    def close(self):
        self._stop.set()
        while not self.q.empty():
            try:
                self.q.get_nowait()
            except queue.Empty:
                break
        self.t.join(timeout=2)
