from repro.data.pipeline import MemmapLM, Prefetcher, SyntheticLM  # noqa: F401
