"""Pluggable serving metrics: a tracker protocol + default sinks.

The engine, scheduler and frontend all emit through one small interface
(:class:`MetricsTracker`) instead of hard-wiring a telemetry backend —
the levanter ``tracker``/``callbacks`` split: call sites name *what*
happened (a counter increment, a latency observation, a gauge level) and
the injected tracker decides *where* it goes.  Production deployments
plug their own exporter; tests and the benches use the bundled
:class:`InMemoryMetrics`; the default is :class:`NullMetrics` so the hot
path pays one no-op virtual call when nobody is listening.

Emitted series (see docs/SERVING.md, "Continuous batching" → metrics):

=============================  =====  ==========================================
name                           kind   meaning
=============================  =====  ==========================================
``engine.requests``            count  RHS batches entering ``solve_batched``
``engine.factor_cache_hit``    count  cached factor reused
``engine.factor_cache_miss``   count  factorization actually ran
``engine.sweeps_per_column``   obs    refinement sweeps spent, per RHS column
``scheduler.queue_ms``         obs    submit → solve-start latency per request
``scheduler.requests``         count  requests completed (rate → req/s)
``scheduler.slot_occupancy``   gauge  occupied / total slots, per sweep
``scheduler.sweeps``           count  continuous-loop sweeps executed
``scheduler.deadline_expired`` count  requests retired at their deadline
``frontend.requests``          count  admissions through the frontend
``frontend.shed``              count  load-shed events, labelled ``tier=``
=============================  =====  ==========================================
"""
from __future__ import annotations

import threading
import time
from typing import Protocol, runtime_checkable


@runtime_checkable
class MetricsTracker(Protocol):
    """What a serving metrics sink must implement.

    Labels are keyword strings (``tracker.inc("frontend.shed", tier=2)``)
    and must have a small, bounded cardinality — implementations key
    storage on ``(name, sorted(labels))``.
    """

    def inc(self, name: str, value: float = 1.0, **labels) -> None:
        """Add ``value`` to a monotonic counter."""
        ...

    def observe(self, name: str, value: float, **labels) -> None:
        """Record one sample of a distribution (latency, sweep count)."""
        ...

    def gauge(self, name: str, value: float, **labels) -> None:
        """Set a point-in-time level (slot occupancy, queue depth)."""
        ...


class NullMetrics:
    """Default tracker: drops everything (one no-op call per event)."""

    def inc(self, name, value=1.0, **labels):
        pass

    def observe(self, name, value, **labels):
        pass

    def gauge(self, name, value, **labels):
        pass


class _Series:
    __slots__ = ("count", "total", "min", "max", "last")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self.last = 0.0

    def add(self, v: float):
        self.count += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        self.last = v

    def summary(self) -> dict:
        return {"count": self.count, "mean": self.total / self.count,
                "min": self.min, "max": self.max, "last": self.last}


def _key(name: str, labels: dict):
    return (name, tuple(sorted(labels.items()))) if labels else (name, ())


class InMemoryMetrics:
    """Thread-safe in-process tracker with a one-shot summary view.

    Counters additionally remember their first/last increment times so
    :meth:`snapshot` can derive rates (``scheduler.requests`` →
    ``req_per_s``) without the caller timing anything.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict = {}
        self._spans: dict = {}          # counter key -> (first_ts, last_ts)
        self._series: dict = {}
        self._gauges: dict = {}

    def inc(self, name, value=1.0, **labels):
        k = _key(name, labels)
        now = time.monotonic()
        with self._lock:
            self._counters[k] = self._counters.get(k, 0.0) + value
            first, _ = self._spans.get(k, (now, now))
            self._spans[k] = (first, now)

    def observe(self, name, value, **labels):
        k = _key(name, labels)
        with self._lock:
            self._series.setdefault(k, _Series()).add(float(value))

    def gauge(self, name, value, **labels):
        with self._lock:
            self._gauges[_key(name, labels)] = float(value)

    @staticmethod
    def _fmt(k):
        name, labels = k
        if not labels:
            return name
        return name + "{" + ",".join(f"{a}={b}" for a, b in labels) + "}"

    def counter(self, name, **labels) -> float:
        with self._lock:
            return self._counters.get(_key(name, labels), 0.0)

    def snapshot(self) -> dict:
        """Summary dict: counters, per-series stats, gauges and rates."""
        with self._lock:
            out = {
                "counters": {self._fmt(k): v
                             for k, v in self._counters.items()},
                "observations": {self._fmt(k): s.summary()
                                 for k, s in self._series.items()},
                "gauges": {self._fmt(k): v for k, v in self._gauges.items()},
                "rates": {},
            }
            for k, (first, last) in self._spans.items():
                if last > first:
                    out["rates"][self._fmt(k) + "_per_s"] = (
                        self._counters[k] / (last - first))
        return out
