"""The consolidated per-request option surface for solve serving.

Every serving entry point — :meth:`SolverEngine.solve`,
:meth:`SolverEngine.solve_batched`, :meth:`BatchScheduler.submit`,
:meth:`BatchScheduler.submit_async` (and the :class:`ServeFrontend` on
top of them) — accepts one :class:`SolveOptions` value instead of the
per-call kwarg spread that used to drift between them (``target_digits``
here, ``fingerprint`` there, ``method`` everywhere).  The old keyword
arguments keep working as deprecated aliases through
:func:`resolve_options`; each use emits a :class:`DeprecationWarning`
pointing at the replacement.

The dataclass is frozen so a single options value can be shared across
requests and threads; per-request variation goes through
``dataclasses.replace`` (or the deprecated kwargs, which do exactly
that under the hood).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Sequence


@dataclasses.dataclass(frozen=True)
class SolveOptions:
    """Per-request solve policy, uniform across all serving entry points.

    ``target_digits`` may be a sequence only for
    :meth:`SolverEngine.solve_batched` (one target per RHS in the
    batch); everywhere else it is a scalar.  ``deadline_ms`` is honored
    by the continuous-batching scheduler: a request whose deadline
    expires mid-loop retires with its best-so-far iterate and
    ``SolveInfo.deadline_expired`` set (windowed drains record it but
    cannot interrupt a running refine call).  ``fingerprint`` is the
    cache hint callers that already ran
    :func:`~repro.serve.engine.matrix_fingerprint` pass to skip the
    redundant O(n) device round-trip.  ``shed_tier`` is stamped by the
    :class:`~repro.serve.frontend.ServeFrontend` when tiered load
    shedding degraded this request (tier 1); it rides through to
    ``SolveInfo.shed_tier``.
    """

    target_digits: float | Sequence[float] = 6.0
    method: str = "ir"                  # "ir" | "gmres"
    cache_key: Any = None
    fingerprint: Any = None             # precomputed matrix_fingerprint
    deadline_ms: float | None = None    # continuous-mode deadline
    col_tol: Any = None                 # explicit per-column tolerances
    shed_tier: int = 0                  # stamped by the frontend

    def __post_init__(self):
        assert self.method in ("ir", "gmres"), self.method
        assert self.shed_tier in (0, 1, 2), self.shed_tier
        if self.deadline_ms is not None:
            assert self.deadline_ms >= 0, self.deadline_ms


#: kwargs accepted as deprecated aliases by every entry point
DEPRECATED_KWARGS = ("target_digits", "method", "cache_key",
                     "fingerprint", "deadline_ms", "col_tol")


def resolve_options(options: SolveOptions | None, kwargs: dict, *,
                    caller: str) -> SolveOptions:
    """Merge an explicit :class:`SolveOptions` with deprecated kwargs.

    ``kwargs`` is the caller's ``**kw`` catch-all; any key from
    :data:`DEPRECATED_KWARGS` is applied on top of ``options`` (or the
    defaults) with one :class:`DeprecationWarning` per call.  Unknown
    keys raise ``TypeError`` — exactly what the old explicit signatures
    did.

    ``_internal=True`` in ``kwargs`` suppresses the warning: the serve
    stack's own layers route through the alias path on purpose (so
    tests and tools that monkeypatch the kwarg-spread entry-point
    signatures keep working) and must not spam the client's warning
    filters for it.
    """
    opts = options if options is not None else SolveOptions()
    internal = bool(kwargs.pop("_internal", False))
    if not kwargs:
        return opts
    unknown = sorted(set(kwargs) - set(DEPRECATED_KWARGS))
    if unknown:
        raise TypeError(
            f"{caller}() got unexpected keyword argument(s) {unknown}; "
            f"per-request policy lives on repro.serve.SolveOptions")
    if not internal:
        warnings.warn(
            f"{caller}(**{{{', '.join(sorted(kwargs))}}}) uses deprecated "
            "keyword aliases; pass repro.serve.SolveOptions instead "
            "(docs/SERVING.md, 'Migrating to SolveOptions')",
            DeprecationWarning, stacklevel=3)
    return dataclasses.replace(opts, **kwargs)
