"""Cross-request batching scheduler for accuracy-targeted SPD solves.

Production solve traffic is bursty and highly redundant: GP
hyperparameter sweeps, K-FAC-style optimizers and ranking backends fire
many concurrent requests against the SAME matrix. Solving them one at a
time pays a full refinement loop — O(n^2) GEMV sweeps plus a dispatch
round-trip — per request. The :class:`BatchScheduler` instead queues
requests, groups the ones that can legally share a factor (same
``cache_key`` AND the same matrix by :func:`~repro.serve.engine
.matrix_fingerprint` AND the same method), stacks their right-hand sides
into one multi-RHS refine call (O(n^2) GEMM sweeps — MXU/BLAS3-shaped
instead of k GEMVs), and splits the per-column results back into
per-request ``(x, SolveInfo)`` pairs.

Per-request accuracy targets survive batching: the stacked call carries
per-column tolerances, and the refinement loop's per-column convergence
masks freeze easy columns while hard neighbors keep sweeping — a batch
is never slower in sweeps than its hardest member, and never burns
sweeps on its easiest.

Ordering guarantees (tested in tests/test_serve.py):

* ``drain()`` returns a result for EVERY pending request, keyed by the
  id that ``submit`` returned.
* Groups are processed in order of their first-submitted request, and
  within a group requests keep submission order (``SolveInfo
  .batch_index`` records each request's slot).
* Groups are chunked to ``max_batch`` columns per refine call, in
  submission order.

This is a host-side loop by design (requests arrive from Python-land
callers); the jit boundary is the stacked refine call inside
``SolverEngine.solve_batched``.
"""
from __future__ import annotations

import dataclasses
import weakref
from typing import Any

import jax.numpy as jnp

from repro.serve.engine import SolveInfo, SolverEngine, matrix_fingerprint


@dataclasses.dataclass
class SolveRequest:
    """One queued solve: A x = b to ``target_digits`` digits."""

    request_id: int
    a: Any
    b: Any
    target_digits: float
    method: str
    cache_key: Any
    n_cols: int                 # 1 for a vector b, k for an (n, k) block


class BatchScheduler:
    """Request loop that batches solves sharing a factor.

    ``submit`` enqueues and returns a request id; ``drain`` processes
    the whole queue and returns ``{request_id: (x, SolveInfo)}``. The
    ``engine`` owns the factor cache, so batching composes with factor
    reuse ACROSS drains: the first drain factorizes once per distinct
    matrix, later drains hit the fingerprint-checked LRU cache.
    """

    def __init__(self, engine: SolverEngine | None = None, *,
                 max_batch: int = 32):
        assert max_batch >= 1, max_batch
        self.engine = engine if engine is not None else SolverEngine()
        self.max_batch = max_batch
        self._queue: list[SolveRequest] = []
        self._fingerprints: dict[int, Any] = {}   # request_id -> fp
        self._next_id = 0
        #: results completed before a failed drain raised; merged into
        #: (and cleared by) the next drain()'s return value
        self._stashed: dict[int, tuple[Any, SolveInfo]] = {}
        #: requests abandoned by the last failed drain (the batch whose
        #: solve raised) — callers inspect these to report/resubmit;
        #: cleared by the next drain
        self.failed: list[SolveRequest] = []
        #: id(a) -> (weakref(a), fingerprint): burst traffic against one
        #: shared matrix fingerprints it once, not once per submit
        self._fp_memo: dict[int, tuple[Any, Any]] = {}

    def __len__(self) -> int:
        return len(self._queue)

    def submit(self, a, b, *, target_digits: float = 6.0,
               method: str = "ir", cache_key=None) -> int:
        """Enqueue a solve; returns the id ``drain()`` keys results by."""
        b = jnp.asarray(b)
        assert b.ndim in (1, 2), b.shape
        assert method in ("ir", "gmres"), method
        rid = self._next_id
        self._next_id += 1
        req = SolveRequest(rid, a, b, float(target_digits), method,
                           cache_key, 1 if b.ndim == 1 else b.shape[1])
        # fingerprint at submit time so grouping can never batch two
        # different matrices that happen to share a cache_key
        self._fingerprints[rid] = self._fingerprint_of(a)
        self._queue.append(req)
        return rid

    def _fingerprint_of(self, a):
        """Memoized matrix_fingerprint: the O(n) device reduction + host
        sync runs once per distinct matrix object, not once per submit.
        The weakref guard makes id() reuse after gc harmless."""
        key = id(a)
        hit = self._fp_memo.get(key)
        if hit is not None and hit[0]() is a:
            return hit[1]
        fp = matrix_fingerprint(a)
        try:
            if len(self._fp_memo) > 64:        # drop dead refs, stay small
                self._fp_memo = {k: v for k, v in self._fp_memo.items()
                                 if v[0]() is not None}
            self._fp_memo[key] = (weakref.ref(a), fp)
        except TypeError:                      # un-weakref-able input
            pass
        return fp

    def _group_key(self, req: SolveRequest):
        return (req.cache_key, self._fingerprints[req.request_id],
                req.method)

    def drain(self) -> dict[int, tuple[Any, SolveInfo]]:
        """Solve everything queued; returns ``{request_id: (x, info)}``.

        Exception-safe: if a batch fails (e.g. a client submitted a
        non-SPD matrix and the factorization raised), the exception
        propagates, but no other work is lost — results completed
        before the failure are stashed and returned by the NEXT drain,
        requests not yet attempted go back on the queue in submission
        order, and the failing batch's requests land in ``self.failed``
        for the caller to report or resubmit (they are NOT re-queued:
        retrying a deterministically failing batch would wedge every
        subsequent drain).
        """
        queue, self._queue = self._queue, []
        groups: list[list[SolveRequest]] = []
        index: dict[Any, int] = {}
        for req in queue:                       # FIFO by first arrival
            key = self._group_key(req)
            if key in index:
                groups[index[key]].append(req)
            else:
                index[key] = len(groups)
                groups.append([req])
        results, self._stashed = self._stashed, {}
        self.failed = []
        in_flight: list[SolveRequest] = []
        try:
            for members in groups:
                for chunk in self._chunks(members):
                    fp = self._fingerprints[chunk[0].request_id]
                    in_flight = chunk          # blamed if the solve raises
                    xs, infos = self.engine.solve_batched(
                        chunk[0].a, [r.b for r in chunk],
                        target_digits=[r.target_digits for r in chunk],
                        method=chunk[0].method,
                        cache_key=chunk[0].cache_key, fingerprint=fp)
                    in_flight = []
                    for req, x, info in zip(chunk, xs, infos):
                        results[req.request_id] = (x, info)
                        self._fingerprints.pop(req.request_id, None)
        except BaseException:
            # only a chunk whose solve actually raised is abandoned; an
            # interrupt between chunks re-queues everything unprocessed
            self.failed = list(in_flight)
            dropped = {r.request_id for r in in_flight}
            for rid in dropped:
                self._fingerprints.pop(rid, None)
            self._stashed = results
            self._queue = [r for r in queue
                           if r.request_id not in results
                           and r.request_id not in dropped] + self._queue
            raise
        return results

    def _chunks(self, members: list[SolveRequest]):
        """Split a group so no refine call exceeds ``max_batch`` columns."""
        chunk: list[SolveRequest] = []
        width = 0
        for req in members:
            if chunk and width + req.n_cols > self.max_batch:
                yield chunk
                chunk, width = [], 0
            chunk.append(req)
            width += req.n_cols
        if chunk:
            yield chunk
