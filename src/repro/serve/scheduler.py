"""Cross-request batching scheduler for accuracy-targeted SPD solves.

Production solve traffic is bursty and highly redundant: GP
hyperparameter sweeps, K-FAC-style optimizers and ranking backends fire
many concurrent requests against the SAME matrix. Solving them one at a
time pays a full refinement loop — O(n^2) GEMV sweeps plus a dispatch
round-trip — per request. The :class:`BatchScheduler` instead queues
requests, groups the ones that can legally share a factor (same
``cache_key`` AND the same matrix by :func:`~repro.serve.engine
.matrix_fingerprint` AND the same method), stacks their right-hand sides
into one multi-RHS refine call (O(n^2) GEMM sweeps — MXU/BLAS3-shaped
instead of k GEMVs), and splits the per-column results back into
per-request ``(x, SolveInfo)`` pairs.

Per-request accuracy targets survive batching: the stacked call carries
per-column tolerances, and the refinement loop's per-column convergence
masks freeze easy columns while hard neighbors keep sweeping — a batch
is never slower in sweeps than its hardest member, and never burns
sweeps on its easiest.

Ordering guarantees (tested in tests/test_serve.py):

* ``drain()`` returns a result for EVERY pending request, keyed by the
  id that ``submit`` returned.
* Groups are processed in order of their first-submitted request, and
  within a group requests keep submission order (``SolveInfo
  .batch_index`` records each request's slot).
* Groups are chunked to ``max_batch`` columns per refine call, in
  submission order.

This is a host-side loop by design (requests arrive from Python-land
callers); the jit boundary is the stacked refine call inside
``SolverEngine.solve_batched``.

**Async drain** (docs/SERVING.md, "Sync vs async drain"): with
``max_wait_ms`` set and :meth:`BatchScheduler.start` called, a
background worker thread drains the queue continuously.
:meth:`~BatchScheduler.submit_async` returns a
:class:`concurrent.futures.Future`; the worker opens a deadline-aware
batching window when the first request of a burst arrives, keeps
collecting arrivals until the oldest pending request has waited
``max_wait_ms`` (or the window holds ``max_batch`` columns), then runs
one drain and resolves the futures. Simple admission control guards the
factor cache: a submission whose matrix would push the number of
DISTINCT pending factors past ``max_pending_factors`` (default: the
engine's ``max_cached_factors``) is rejected with
:class:`SchedulerOverload` instead of queued — a window with more
distinct matrices than cache slots would evict factors still needed by
later groups of the same window (thrash), so the backpressure lands on
the client that would cause it.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import weakref
from concurrent.futures import Future
from typing import Any

import jax.numpy as jnp

from repro.serve.engine import SolveInfo, SolverEngine, matrix_fingerprint


class SchedulerOverload(RuntimeError):
    """Submission rejected by admission control (factor cache would
    thrash). Clients should back off and resubmit, or raise the
    engine's ``max_cached_factors`` / the scheduler's
    ``max_pending_factors``."""


@dataclasses.dataclass
class SolveRequest:
    """One queued solve: A x = b to ``target_digits`` digits."""

    request_id: int
    a: Any
    b: Any
    target_digits: float
    method: str
    cache_key: Any
    n_cols: int                 # 1 for a vector b, k for an (n, k) block


class BatchScheduler:
    """Request loop that batches solves sharing a factor.

    ``submit`` enqueues and returns a request id; ``drain`` processes
    the whole queue and returns ``{request_id: (x, SolveInfo)}``. The
    ``engine`` owns the factor cache, so batching composes with factor
    reuse ACROSS drains: the first drain factorizes once per distinct
    matrix, later drains hit the fingerprint-checked LRU cache.

    With ``max_wait_ms`` set, :meth:`start` spawns a background worker
    and :meth:`submit_async` returns futures — the deadline-aware async
    request loop (module docstring; lifecycle in docs/SERVING.md).
    ``drain()`` stays available for synchronous use, but don't mix the
    two styles on one scheduler instance: the worker assumes it is the
    only drainer.
    """

    def __init__(self, engine: SolverEngine | None = None, *,
                 max_batch: int | None = None,
                 max_wait_ms: float | None = None,
                 max_pending_factors: int | None = None):
        self.engine = engine if engine is not None else SolverEngine()
        if max_batch is None:
            # tuning-DB serving geometry for this ladder/backend
            # (docs/TUNING.md), falling back to the pre-tuner 32
            from repro import tune
            max_batch = tune.decide(
                256, tune.ladder_key(self.engine.cfg),
                db=self.engine._tuning_db).max_batch
        assert max_batch >= 1, max_batch
        self.max_batch = max_batch
        #: async batching window; None = sync-only scheduler
        self.max_wait_ms = max_wait_ms
        #: admission-control bound on distinct pending factors
        self.max_pending_factors = (
            max_pending_factors if max_pending_factors is not None
            else self.engine.max_cached_factors)
        assert self.max_pending_factors >= 1, self.max_pending_factors
        self._lock = threading.RLock()
        self._cv = threading.Condition(self._lock)
        self._worker: threading.Thread | None = None
        self._stop_flag = False
        self._window_start: float | None = None
        self._futures: dict[int, Future] = {}
        self._queue: list[SolveRequest] = []
        self._fingerprints: dict[int, Any] = {}   # request_id -> fp
        self._next_id = 0
        #: results completed before a failed drain raised; merged into
        #: (and cleared by) the next drain()'s return value
        self._stashed: dict[int, tuple[Any, SolveInfo]] = {}
        #: requests abandoned by the last failed drain (the batch whose
        #: solve raised) — callers inspect these to report/resubmit;
        #: cleared by the next drain
        self.failed: list[SolveRequest] = []
        #: id(a) -> (weakref(a), fingerprint): burst traffic against one
        #: shared matrix fingerprints it once, not once per submit
        self._fp_memo: dict[int, tuple[Any, Any]] = {}

    def __len__(self) -> int:
        return len(self._queue)

    def submit(self, a, b, *, target_digits: float = 6.0,
               method: str = "ir", cache_key=None) -> int:
        """Enqueue a solve; returns the id ``drain()`` keys results by."""
        b = jnp.asarray(b)
        assert b.ndim in (1, 2), b.shape
        assert method in ("ir", "gmres"), method
        # fingerprint at submit time so grouping can never batch two
        # different matrices that happen to share a cache_key
        fp = self._fingerprint_of(a)
        with self._cv:
            rid = self._next_id
            self._next_id += 1
            req = SolveRequest(rid, a, b, float(target_digits), method,
                               cache_key, 1 if b.ndim == 1 else b.shape[1])
            self._fingerprints[rid] = fp
            if not self._queue:
                self._window_start = time.monotonic()
            self._queue.append(req)
            self._cv.notify_all()
        return rid

    # -- async drain --------------------------------------------------------
    def submit_async(self, a, b, *, target_digits: float = 6.0,
                     method: str = "ir", cache_key=None) -> Future:
        """Enqueue a solve for the background worker; returns a Future
        resolving to ``(x, SolveInfo)``.

        Requires a running worker (:meth:`start`). Raises
        :class:`SchedulerOverload` when admission control rejects the
        request (the submission would put more distinct factors in
        flight than the factor cache holds).
        """
        fp = self._fingerprint_of(a)
        with self._cv:
            assert self._worker is not None, (
                "submit_async needs the async worker: call start() first")
            self._admit((cache_key, fp))
            rid = self.submit(a, b, target_digits=target_digits,
                              method=method, cache_key=cache_key)
            fut: Future = Future()
            self._futures[rid] = fut
        return fut

    def _admit(self, key):
        """Reject a NEW distinct factor when the pending set is full."""
        pending = {(r.cache_key, self._fingerprints[r.request_id])
                   for r in self._queue}
        if key not in pending and len(pending) >= self.max_pending_factors:
            raise SchedulerOverload(
                f"{len(pending)} distinct factors already pending "
                f"(max_pending_factors={self.max_pending_factors})")

    def start(self) -> None:
        """Spawn the background drain worker (idempotent)."""
        assert self.max_wait_ms is not None, (
            "async drain needs a batching window: pass max_wait_ms")
        with self._cv:
            if self._worker is not None:
                if self._worker.is_alive():
                    return                   # one drainer only
                self._worker = None          # finished after a timed-out stop
            self._stop_flag = False
            self._worker = threading.Thread(
                target=self._run, name="BatchScheduler-drain", daemon=True)
            self._worker.start()

    def stop(self, timeout: float | None = None) -> None:
        """Stop the worker; pending requests are drained first.

        If ``timeout`` expires while the worker is still mid-drain, the
        worker stays registered (and stopping): a later :meth:`start`
        is a no-op until it actually exits, so two drainers can never
        race one queue.
        """
        with self._cv:
            worker = self._worker
            if worker is None:
                return
            self._stop_flag = True
            self._cv.notify_all()
        worker.join(timeout)
        with self._cv:
            if not worker.is_alive():
                self._worker = None

    def _pending_cols(self) -> int:
        return sum(r.n_cols for r in self._queue)

    def _run(self):
        """Worker loop: deadline-aware batching window, then one drain.

        The window opens when the first request of a burst arrives
        (``submit`` stamps ``_window_start``) and closes when the oldest
        pending request has waited ``max_wait_ms`` or the queue holds a
        full batch — so a lone request never waits longer than the
        window, while a burst inside it batches into one refine call.
        """
        while True:
            with self._cv:
                while not self._queue and not self._stop_flag:
                    self._cv.wait()
                if not self._queue:         # stop requested, queue empty
                    return
                deadline = self._window_start + self.max_wait_ms / 1e3
                while (not self._stop_flag
                       and self._pending_cols() < self.max_batch):
                    left = deadline - time.monotonic()
                    if left <= 0:
                        break
                    self._cv.wait(left)
            try:
                results = self.drain()
            except Exception as exc:  # noqa: BLE001 — forwarded to futures
                with self._cv:
                    for req in self.failed:
                        fut = self._futures.pop(req.request_id, None)
                        if fut is not None:
                            fut.set_exception(exc)
                    # flush results completed before the failure straight
                    # to their futures; results of SYNC-submitted
                    # requests stay stashed for the next drain() to
                    # return. Re-queued requests ride the next window.
                    stashed, self._stashed = self._stashed, {}
                    for rid, out in stashed.items():
                        fut = self._futures.pop(rid, None)
                        if fut is not None:
                            fut.set_result(out)
                        else:
                            self._stashed[rid] = out
                continue
            with self._cv:
                for rid, out in results.items():
                    fut = self._futures.pop(rid, None)
                    if fut is not None:
                        fut.set_result(out)

    def _fingerprint_of(self, a):
        """Memoized matrix_fingerprint: the O(n) device reduction + host
        sync runs once per distinct matrix object, not once per submit.
        The weakref guard makes id() reuse after gc harmless."""
        key = id(a)
        hit = self._fp_memo.get(key)
        if hit is not None and hit[0]() is a:
            return hit[1]
        fp = matrix_fingerprint(a)
        try:
            if len(self._fp_memo) > 64:        # drop dead refs, stay small
                self._fp_memo = {k: v for k, v in self._fp_memo.items()
                                 if v[0]() is not None}
            self._fp_memo[key] = (weakref.ref(a), fp)
        except TypeError:                      # un-weakref-able input
            pass
        return fp

    def _group_key(self, req: SolveRequest):
        return (req.cache_key, self._fingerprints[req.request_id],
                req.method)

    def drain(self) -> dict[int, tuple[Any, SolveInfo]]:
        """Solve everything queued; returns ``{request_id: (x, info)}``.

        Exception-safe: if a batch fails (e.g. a client submitted a
        non-SPD matrix and the factorization raised), the exception
        propagates, but no other work is lost — results completed
        before the failure are stashed and returned by the NEXT drain,
        requests not yet attempted go back on the queue in submission
        order, and the failing batch's requests land in ``self.failed``
        for the caller to report or resubmit (they are NOT re-queued:
        retrying a deterministically failing batch would wedge every
        subsequent drain).
        """
        with self._lock:
            queue, self._queue = self._queue, []
            results, self._stashed = self._stashed, {}
            self.failed = []
        groups: list[list[SolveRequest]] = []
        index: dict[Any, int] = {}
        for req in queue:                       # FIFO by first arrival
            key = self._group_key(req)
            if key in index:
                groups[index[key]].append(req)
            else:
                index[key] = len(groups)
                groups.append([req])
        in_flight: list[SolveRequest] = []
        try:
            for members in groups:
                for chunk in self._chunks(members):
                    fp = self._fingerprints[chunk[0].request_id]
                    in_flight = chunk          # blamed if the solve raises
                    xs, infos = self.engine.solve_batched(
                        chunk[0].a, [r.b for r in chunk],
                        target_digits=[r.target_digits for r in chunk],
                        method=chunk[0].method,
                        cache_key=chunk[0].cache_key, fingerprint=fp)
                    in_flight = []
                    for req, x, info in zip(chunk, xs, infos):
                        results[req.request_id] = (x, info)
                        self._fingerprints.pop(req.request_id, None)
        except BaseException:
            # only a chunk whose solve actually raised is abandoned; an
            # interrupt between chunks re-queues everything unprocessed
            with self._lock:
                self.failed = list(in_flight)
                dropped = {r.request_id for r in in_flight}
                for rid in dropped:
                    self._fingerprints.pop(rid, None)
                self._stashed = results
                self._queue = [r for r in queue
                               if r.request_id not in results
                               and r.request_id not in dropped] + self._queue
            raise
        return results

    def _chunks(self, members: list[SolveRequest]):
        """Split a group so no refine call exceeds ``max_batch`` columns."""
        chunk: list[SolveRequest] = []
        width = 0
        for req in members:
            if chunk and width + req.n_cols > self.max_batch:
                yield chunk
                chunk, width = [], 0
            chunk.append(req)
            width += req.n_cols
        if chunk:
            yield chunk
