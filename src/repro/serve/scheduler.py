"""Cross-request batching scheduler for accuracy-targeted SPD solves.

Production solve traffic is bursty and highly redundant: GP
hyperparameter sweeps, K-FAC-style optimizers and ranking backends fire
many concurrent requests against the SAME matrix. Solving them one at a
time pays a full refinement loop — O(n^2) GEMV sweeps plus a dispatch
round-trip — per request. The :class:`BatchScheduler` instead queues
requests, groups the ones that can legally share a factor (same
``cache_key`` AND the same matrix by :func:`~repro.serve.engine
.matrix_fingerprint` AND the same method), stacks their right-hand sides
into one multi-RHS refine call (O(n^2) GEMM sweeps — MXU/BLAS3-shaped
instead of k GEMVs), and splits the per-column results back into
per-request ``(x, SolveInfo)`` pairs.

Per-request accuracy targets survive batching: the stacked call carries
per-column tolerances, and the refinement loop's per-column convergence
masks freeze easy columns while hard neighbors keep sweeping — a batch
is never slower in sweeps than its hardest member, and never burns
sweeps on its easiest.

Ordering guarantees (tested in tests/test_serve.py):

* ``drain()`` returns a result for EVERY pending request, keyed by the
  id that ``submit`` returned.
* Groups are processed in order of their first-submitted request, and
  within a group requests keep submission order (``SolveInfo
  .batch_index`` records each request's slot).
* Groups are chunked to ``max_batch`` columns per refine call, in
  submission order.

This is a host-side loop by design (requests arrive from Python-land
callers); the jit boundary is the stacked refine call inside
``SolverEngine.solve_batched`` (windowed mode) or the jitted slot sweep
of :class:`~repro.core.refine.RefineStepper` (continuous mode).

**Async drain** (docs/SERVING.md, "Sync vs async drain"): with
``max_wait_ms`` set and :meth:`BatchScheduler.start` called, a
background worker thread drains the queue continuously.
:meth:`~BatchScheduler.submit_async` returns a
:class:`concurrent.futures.Future`; the worker opens a deadline-aware
batching window when the first request of a burst arrives, keeps
collecting arrivals until the oldest pending request has waited
``max_wait_ms`` (or the window holds ``max_batch`` columns), then runs
one drain and resolves the futures. Simple admission control guards the
factor cache: a submission whose matrix would push the number of
DISTINCT pending factors past ``max_pending_factors`` (default: the
engine's ``max_cached_factors``) is rejected with
:class:`SchedulerOverload` instead of queued — a window with more
distinct matrices than cache slots would evict factors still needed by
later groups of the same window (thrash), so the backpressure lands on
the client that would cause it. (For graduated backpressure — degrade
the accuracy target before rejecting — stack a
:class:`~repro.serve.frontend.ServeFrontend` on top.)

**Continuous batching** (docs/SERVING.md, "Continuous batching"): with
``continuous=True`` the worker replaces the batching *window* with a
re-entrant slot loop (``max_batch`` slots wide) per factor group.
Converged columns RETIRE between sweeps — their request's future
resolves while neighbors keep refining — and freed slots are refilled
mid-flight from queued requests sharing the factor fingerprint, so a
request's latency tracks its own difficulty instead of the window's
slowest member. Classic IR is column-local, so a column's trajectory is
identical in either mode (tests/test_serve_continuous.py pins
continuous == window column-for-column); GMRES-IR and distributed-path
requests fall back to a windowed drain of their group. Per-request
``deadline_ms`` is enforced between sweeps: an expired request retires
immediately with its best-so-far iterate and ``SolveInfo
.deadline_expired`` set.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import weakref
from concurrent.futures import Future
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.serve.engine import SolveInfo, SolverEngine, matrix_fingerprint
from repro.serve.metrics import MetricsTracker
from repro.serve.options import SolveOptions, resolve_options


class SchedulerOverload(RuntimeError):
    """Submission rejected by admission control (factor cache would
    thrash) or by the frontend's hard shedding tier. Clients should back
    off and resubmit, or raise the engine's ``max_cached_factors`` / the
    scheduler's ``max_pending_factors`` / the frontend's
    ``hard_pending``."""


@dataclasses.dataclass
class SolveRequest:
    """One queued solve: A x = b per ``options``.

    ``options`` is the fully resolved per-request policy (scalar
    ``target_digits``); ``submitted_at`` the ``time.monotonic()`` stamp
    queue latency and deadlines are measured from. The flat accessors
    (``req.target_digits`` etc.) are kept for callers that predate
    :class:`~repro.serve.options.SolveOptions`.
    """

    request_id: int
    a: Any
    b: Any
    options: SolveOptions
    n_cols: int                 # 1 for a vector b, k for an (n, k) block
    submitted_at: float = 0.0   # time.monotonic() at submit

    @property
    def target_digits(self) -> float:
        return self.options.target_digits

    @property
    def method(self) -> str:
        return self.options.method

    @property
    def cache_key(self):
        return self.options.cache_key

    @property
    def deadline_ms(self):
        return self.options.deadline_ms

    @property
    def shed_tier(self) -> int:
        return self.options.shed_tier


@dataclasses.dataclass
class _LiveRequest:
    """A request currently holding slots in the continuous loop."""

    req: SolveRequest
    slots: list                  # slot indices still holding its columns
    queue_ms: float              # submit -> join latency
    deadline: float | None       # absolute monotonic deadline
    cached: bool                 # factor_cached for its SolveInfo
    hist: dict                   # col index -> [rel0, per-sweep rel, ...]
    cols: dict = dataclasses.field(default_factory=dict)
    expired: bool = False        # retired by deadline, not convergence


class BatchScheduler:
    """Request loop that batches solves sharing a factor.

    ``submit`` enqueues and returns a request id; ``drain`` processes
    the whole queue and returns ``{request_id: (x, SolveInfo)}``. The
    ``engine`` owns the factor cache, so batching composes with factor
    reuse ACROSS drains: the first drain factorizes once per distinct
    matrix, later drains hit the fingerprint-checked LRU cache.

    With ``max_wait_ms`` set, :meth:`start` spawns a background worker
    and :meth:`submit_async` returns futures — the deadline-aware async
    request loop (module docstring; lifecycle in docs/SERVING.md).
    ``drain()`` stays available for synchronous use, but don't mix the
    two styles on one scheduler instance: the worker assumes it is the
    only drainer.

    With ``continuous=True`` the worker runs the slot loop instead
    (module docstring, "Continuous batching"); ``max_wait_ms`` is then
    optional — arrivals join mid-flight, there is no window to bound.
    ``metrics`` defaults to the engine's tracker so one injected sink
    sees the whole serving stack.
    """

    def __init__(self, engine: SolverEngine | None = None, *,
                 max_batch: int | None = None,
                 max_wait_ms: float | None = None,
                 max_pending_factors: int | None = None,
                 continuous: bool = False,
                 metrics: MetricsTracker | None = None):
        self.engine = engine if engine is not None else SolverEngine()
        if max_batch is None:
            # tuning-DB serving geometry for this ladder/backend
            # (docs/TUNING.md), falling back to the pre-tuner 32
            from repro import tune
            max_batch = tune.decide(
                256, tune.ladder_key(self.engine.cfg),
                db=self.engine._tuning_db).max_batch
        assert max_batch >= 1, max_batch
        self.max_batch = max_batch
        #: async batching window; None = sync-only (or continuous)
        self.max_wait_ms = max_wait_ms
        #: continuous (slot-loop) worker instead of windowed drains
        self.continuous = continuous
        #: admission-control bound on distinct pending factors
        self.max_pending_factors = (
            max_pending_factors if max_pending_factors is not None
            else self.engine.max_cached_factors)
        assert self.max_pending_factors >= 1, self.max_pending_factors
        self.metrics: MetricsTracker = (metrics if metrics is not None
                                        else self.engine.metrics)
        self._lock = threading.RLock()
        self._cv = threading.Condition(self._lock)
        self._worker: threading.Thread | None = None
        self._stop_flag = False
        self._window_start: float | None = None
        self._futures: dict[int, Future] = {}
        self._queue: list[SolveRequest] = []
        self._fingerprints: dict[int, Any] = {}   # request_id -> fp
        self._next_id = 0
        #: results completed before a failed drain raised; merged into
        #: (and cleared by) the next drain()'s return value
        self._stashed: dict[int, tuple[Any, SolveInfo]] = {}
        #: requests abandoned by the last failed drain (the batch whose
        #: solve raised) — callers inspect these to report/resubmit;
        #: cleared by the next drain
        self.failed: list[SolveRequest] = []
        #: id(a) -> (weakref(a), fingerprint): burst traffic against one
        #: shared matrix fingerprints it once, not once per submit
        self._fp_memo: dict[int, tuple[Any, Any]] = {}

    def __len__(self) -> int:
        return len(self._queue)

    def submit(self, a, b, options: SolveOptions | None = None,
               **kw) -> int:
        """Enqueue a solve; returns the id ``drain()`` keys results by.

        Pre-``SolveOptions`` kwargs (``target_digits=``, ``method=``,
        ``cache_key=``) keep working as deprecated aliases.
        """
        opts = resolve_options(options, kw, caller="BatchScheduler.submit")
        b = jnp.asarray(b)
        assert b.ndim in (1, 2), b.shape
        assert np.isscalar(opts.target_digits), (
            "scheduler requests carry one target each; per-column "
            "sequences belong to SolverEngine.solve_batched")
        opts = dataclasses.replace(opts,
                                   target_digits=float(opts.target_digits))
        # fingerprint at submit time so grouping can never batch two
        # different matrices that happen to share a cache_key
        fp = (opts.fingerprint if opts.fingerprint is not None
              else self._fingerprint_of(a))
        with self._cv:
            rid = self._next_id
            self._next_id += 1
            req = SolveRequest(rid, a, b, opts,
                               1 if b.ndim == 1 else b.shape[1],
                               submitted_at=time.monotonic())
            self._fingerprints[rid] = fp
            if not self._queue:
                self._window_start = time.monotonic()
            self._queue.append(req)
            self._cv.notify_all()
        return rid

    # -- async drain --------------------------------------------------------
    def submit_async(self, a, b, options: SolveOptions | None = None,
                     **kw) -> Future:
        """Enqueue a solve for the background worker; returns a Future
        resolving to ``(x, SolveInfo)``.

        Requires a running worker (:meth:`start`). Raises
        :class:`SchedulerOverload` when admission control rejects the
        request (the submission would put more distinct factors in
        flight than the factor cache holds) and ``RuntimeError`` when
        the scheduler is stopping — a submission racing :meth:`stop`
        either completes (it beat the stop flag, so the worker's final
        sweep drains it) or raises here; it is never silently dropped.
        Deprecated kwarg aliases as in :meth:`submit`.
        """
        opts = resolve_options(options, kw,
                               caller="BatchScheduler.submit_async")
        fp = (opts.fingerprint if opts.fingerprint is not None
              else self._fingerprint_of(a))
        opts = dataclasses.replace(opts, fingerprint=fp)
        with self._cv:
            assert self._worker is not None, (
                "submit_async needs the async worker: call start() first")
            if self._stop_flag:
                raise RuntimeError(
                    "scheduler is stopping; submission refused")
            self._admit((opts.cache_key, fp))
            rid = self.submit(a, b, opts)
            fut: Future = Future()
            self._futures[rid] = fut
        return fut

    def _admit(self, key):
        """Reject a NEW distinct factor when the pending set is full."""
        pending = {(r.cache_key, self._fingerprints[r.request_id])
                   for r in self._queue}
        if key not in pending and len(pending) >= self.max_pending_factors:
            raise SchedulerOverload(
                f"{len(pending)} distinct factors already pending "
                f"(max_pending_factors={self.max_pending_factors})")

    def start(self) -> None:
        """Spawn the background drain worker (idempotent)."""
        assert self.max_wait_ms is not None or self.continuous, (
            "async drain needs a batching window (max_wait_ms) or "
            "continuous=True")
        with self._cv:
            if self._worker is not None:
                if self._worker.is_alive():
                    return                   # one drainer only
                self._worker = None          # finished after a timed-out stop
            self._stop_flag = False
            self._worker = threading.Thread(
                target=self._run, name="BatchScheduler-drain", daemon=True)
            self._worker.start()

    def stop(self, timeout: float | None = None) -> None:
        """Stop the worker; pending requests are drained first.

        A :meth:`submit_async` racing this call either completes (its
        request landed before the stop flag was set, and the worker
        drains the queue before exiting — the flag is set and checked
        under the same lock as enqueue) or raises ``RuntimeError`` at
        submission; its future is never silently dropped. As a backstop,
        anything still queued with a future after the worker exits is
        drained inline here.

        If ``timeout`` expires while the worker is still mid-drain, the
        worker stays registered (and stopping): a later :meth:`start`
        is a no-op until it actually exits, so two drainers can never
        race one queue.
        """
        with self._cv:
            worker = self._worker
            if worker is None:
                return
            self._stop_flag = True
            self._cv.notify_all()
        worker.join(timeout)
        with self._cv:
            if not worker.is_alive():
                self._worker = None
        self._flush_leftovers()

    def _flush_leftovers(self):
        """Resolve futures of requests the dead worker never saw."""
        while True:
            with self._cv:
                if self._worker is not None or not any(
                        r.request_id in self._futures for r in self._queue):
                    return
            try:
                results = self.drain()
            except Exception as exc:  # noqa: BLE001 — forwarded to futures
                with self._cv:
                    for req in self.failed:
                        fut = self._futures.pop(req.request_id, None)
                        if fut is not None:
                            fut.set_exception(exc)
                continue
            with self._cv:
                for rid, out in results.items():
                    fut = self._futures.pop(rid, None)
                    if fut is not None:
                        fut.set_result(out)

    def _pending_cols(self) -> int:
        return sum(r.n_cols for r in self._queue)

    def pending_cols(self) -> int:
        """Queued RHS columns not yet in a refine loop — the load signal
        the :class:`~repro.serve.frontend.ServeFrontend` sheds on."""
        with self._lock:
            return self._pending_cols()

    def _run(self):
        """Worker loop: deadline-aware batching window, then one drain.

        The window opens when the first request of a burst arrives
        (``submit`` stamps ``_window_start``) and closes when the oldest
        pending request has waited ``max_wait_ms`` or the queue holds a
        full batch — so a lone request never waits longer than the
        window, while a burst inside it batches into one refine call.
        ``continuous=True`` replaces the window with the slot loop
        (:meth:`_run_continuous`).
        """
        if self.continuous:
            return self._run_continuous()
        while True:
            with self._cv:
                while not self._queue and not self._stop_flag:
                    self._cv.wait()
                if not self._queue:         # stop requested, queue empty
                    return
                deadline = self._window_start + self.max_wait_ms / 1e3
                while (not self._stop_flag
                       and self._pending_cols() < self.max_batch):
                    left = deadline - time.monotonic()
                    if left <= 0:
                        break
                    self._cv.wait(left)
            try:
                results = self.drain()
            except Exception as exc:  # noqa: BLE001 — forwarded to futures
                with self._cv:
                    for req in self.failed:
                        fut = self._futures.pop(req.request_id, None)
                        if fut is not None:
                            fut.set_exception(exc)
                    # flush results completed before the failure straight
                    # to their futures; results of SYNC-submitted
                    # requests stay stashed for the next drain() to
                    # return. Re-queued requests ride the next window.
                    stashed, self._stashed = self._stashed, {}
                    for rid, out in stashed.items():
                        fut = self._futures.pop(rid, None)
                        if fut is not None:
                            fut.set_result(out)
                        else:
                            self._stashed[rid] = out
                continue
            with self._cv:
                for rid, out in results.items():
                    fut = self._futures.pop(rid, None)
                    if fut is not None:
                        fut.set_result(out)

    # -- continuous batching ------------------------------------------------
    def _run_continuous(self):
        """Continuous worker: head-of-queue group -> slot refine loop.

        Groups are served in order of their first-submitted request,
        like windowed drains. GMRES-IR, distributed-path and
        wider-than-the-block requests fall back to a windowed drain of
        their group (:meth:`_drain_group`) — the slot loop only accepts
        what can legally retire per column.
        """
        while True:
            with self._cv:
                while not self._queue and not self._stop_flag:
                    self._cv.wait()
                if not self._queue:         # stop requested, queue empty
                    return
                head = self._queue[0]
                key = self._group_key(head)
                n = head.b.shape[0]
                wide = head.n_cols > self.max_batch
            if (head.method != "ir" or wide
                    or self.engine._use_dist(n)):
                self._drain_group(key)
            else:
                self._continuous_group(key, head.a)

    def _continuous_group(self, key, a):
        """Run one factor group through the slot loop until drained.

        Per iteration: admit queued group members into free slots
        (mid-flight join), force-retire deadline-expired requests, run
        one masked sweep, then retire converged/stalled/exhausted slots
        and resolve any request whose last column just retired. The
        loop exits when the block is empty and no matching request is
        queued.
        """
        cache_key, fp, _ = key
        stepper, base_solve, cached = self.engine.continuous_stepper(
            a, slots=self.max_batch, cache_key=cache_key, fingerprint=fp)
        state = stepper.init()
        slot_owner: list = [None] * self.max_batch   # slot -> (rid, col)
        live: dict[int, _LiveRequest] = {}
        while True:
            state = self._cb_admit(key, stepper, state, slot_owner, live,
                                   base_solve, cached)
            if not live:
                return                      # block empty, queue has no match
            state = self._cb_expire(stepper, state, slot_owner, live)
            if not live:
                continue
            if stepper.active_mask(state).any():
                state, stepped = stepper.step(state)
                self.metrics.inc("scheduler.sweeps")
                rel = np.asarray(state.rel)
                for s in np.flatnonzero(stepped):
                    owner = slot_owner[s]
                    if owner is not None:
                        live[owner[0]].hist[owner[1]].append(float(rel[s]))
            self.metrics.gauge(
                "scheduler.slot_occupancy",
                float(np.asarray(state.occ).sum()) / self.max_batch)
            done = [s for s in np.flatnonzero(stepper.done_mask(state))
                    if slot_owner[s] is not None]
            state = self._cb_retire(stepper, state, slot_owner, live, done,
                                    expired=False)

    def _cb_admit(self, key, stepper, state, slot_owner, live, base_solve,
                  cached):
        """Join queued group members into free slots (FIFO, no overtake:
        a member that doesn't fit blocks later members of ITS group so
        submission order holds; other groups are untouched)."""
        room = sum(1 for o in slot_owner if o is None)
        take: list[SolveRequest] = []
        with self._cv:
            blocked = False
            rest = []
            for r in self._queue:
                if (self._group_key(r) == key and not blocked
                        and r.n_cols <= room):
                    take.append(r)
                    room -= r.n_cols
                else:
                    if self._group_key(r) == key:
                        blocked = True
                    rest.append(r)
            if take:
                self._queue = rest
                self._cv.notify_all()
        if not take:
            return state
        now = time.monotonic()
        free = [i for i, o in enumerate(slot_owner) if o is None]
        bblk = jnp.concatenate(
            [r.b[:, None] if r.b.ndim == 1 else r.b for r in take],
            axis=1).astype(stepper.rdtype)
        x0 = base_solve(bblk)               # the window path's x0, unscaled
        tols = np.concatenate([
            np.full(r.n_cols, 10.0 ** -self.engine._clamp(r.target_digits))
            for r in take])
        used = free[:bblk.shape[1]]
        state = stepper.join(state, used, bblk, x0, tols)
        rel = np.asarray(state.rel)
        pos = 0
        for r in take:
            rslots = used[pos:pos + r.n_cols]
            pos += r.n_cols
            for ci, s in enumerate(rslots):
                slot_owner[s] = (r.request_id, ci)
            qms = (now - r.submitted_at) * 1e3
            live[r.request_id] = _LiveRequest(
                req=r, slots=list(rslots), queue_ms=qms,
                deadline=(r.submitted_at + r.deadline_ms / 1e3
                          if r.deadline_ms is not None else None),
                cached=cached,
                hist={ci: [float(rel[s])] for ci, s in enumerate(rslots)})
            self.metrics.observe("scheduler.queue_ms", qms)
        return state

    def _cb_expire(self, stepper, state, slot_owner, live):
        """Force-retire live requests whose deadline has passed; they
        resolve with the best iterate seen so far."""
        now = time.monotonic()
        for rid in list(live):
            lv = live[rid]
            if lv.deadline is not None and now >= lv.deadline and lv.slots:
                state = self._cb_retire(stepper, state, slot_owner, live,
                                        list(lv.slots), expired=True)
        return state

    def _cb_retire(self, stepper, state, slot_owner, live, slots, *,
                   expired):
        """Retire ``slots`` and resolve requests with no columns left."""
        if not slots:
            return state
        state, results = stepper.retire(state, slots)
        finished = set()
        for s, res in zip(slots, results):
            rid, ci = slot_owner[s]
            slot_owner[s] = None
            lv = live[rid]
            lv.slots.remove(s)
            lv.cols[ci] = res
            lv.expired = lv.expired or expired
            if not lv.slots:
                finished.add(rid)
        for rid in finished:
            self._cb_resolve(live.pop(rid))
        return state

    def _cb_resolve(self, lv: _LiveRequest):
        """Assemble ``(x, SolveInfo)`` from retired columns and resolve
        the request's future (or stash for a sync caller)."""
        req = lv.req
        k = req.n_cols
        xcols = [lv.cols[ci][0] for ci in range(k)]
        x = xcols[0] if req.b.ndim == 1 else jnp.stack(xcols, axis=1)
        info = SolveInfo(
            ladder=self.engine.ladder_name, method="ir",
            sweeps=max(lv.cols[ci][2] for ci in range(k)),
            residual=max(lv.cols[ci][1] for ci in range(k)),
            converged=all(lv.cols[ci][3] for ci in range(k)),
            target_digits=self.engine._clamp(req.target_digits),
            factor_cached=lv.cached, queue_ms=lv.queue_ms,
            shed_tier=req.shed_tier, deadline_expired=lv.expired,
            history=tuple(tuple(lv.hist[ci]) for ci in range(k)))
        self.metrics.inc("scheduler.requests")
        if lv.expired:
            self.metrics.inc("scheduler.deadline_expired")
        with self._cv:
            self._fingerprints.pop(req.request_id, None)
            fut = self._futures.pop(req.request_id, None)
            if fut is None:
                self._stashed[req.request_id] = (x, info)
        if fut is not None:
            fut.set_result((x, info))

    def _drain_group(self, key):
        """Windowed drain of ONE group — the continuous worker's
        fallback for GMRES-IR / distributed / oversized requests. A
        failing chunk forwards its exception to its futures (and
        ``self.failed``) without taking down the worker."""
        with self._lock:
            take = [r for r in self._queue if self._group_key(r) == key]
            self._queue = [r for r in self._queue
                           if self._group_key(r) != key]
        for chunk in self._chunks(take):
            start = time.monotonic()
            try:
                xs, infos = self._solve_chunk(chunk)
            except Exception as exc:  # noqa: BLE001 — forwarded
                with self._cv:
                    self.failed = list(chunk)
                    for req in chunk:
                        self._fingerprints.pop(req.request_id, None)
                        fut = self._futures.pop(req.request_id, None)
                        if fut is not None:
                            fut.set_exception(exc)
                continue
            for req, x, info in zip(chunk, xs, infos):
                out = (x, self._stamp(info, req, start))
                with self._cv:
                    self._fingerprints.pop(req.request_id, None)
                    fut = self._futures.pop(req.request_id, None)
                    if fut is None:
                        self._stashed[req.request_id] = out
                if fut is not None:
                    fut.set_result(out)

    # -- shared drain plumbing ----------------------------------------------
    def _solve_chunk(self, chunk: list[SolveRequest]):
        """One stacked refine call for a chunk of grouped requests.

        Deliberately routes through the engine's kwarg-alias path (with
        the warning suppressed via ``_internal``) rather than a
        positional ``SolveOptions``: tests and tools monkeypatch
        ``engine.solve_batched`` with the kwarg-spread signature, and
        this keeps that seam stable.
        """
        return self.engine.solve_batched(
            chunk[0].a, [r.b for r in chunk],
            target_digits=[r.target_digits for r in chunk],
            method=chunk[0].method, cache_key=chunk[0].cache_key,
            fingerprint=self._fingerprints[chunk[0].request_id],
            _internal=True)

    def _stamp(self, info: SolveInfo, req: SolveRequest,
               start: float) -> SolveInfo:
        """Fill the serving-layer SolveInfo fields for one request."""
        qms = (start - req.submitted_at) * 1e3
        self.metrics.observe("scheduler.queue_ms", qms)
        self.metrics.inc("scheduler.requests")
        # a windowed drain can't interrupt a running refine call, but it
        # still reports requests whose deadline had passed before the
        # solve even started
        expired = (req.deadline_ms is not None and qms > req.deadline_ms)
        if expired:
            self.metrics.inc("scheduler.deadline_expired")
        return dataclasses.replace(info, queue_ms=qms,
                                   shed_tier=req.shed_tier,
                                   deadline_expired=expired)

    def _fingerprint_of(self, a):
        """Memoized matrix_fingerprint: the O(n) device reduction + host
        sync runs once per distinct matrix object, not once per submit.
        The weakref guard makes id() reuse after gc harmless."""
        key = id(a)
        hit = self._fp_memo.get(key)
        if hit is not None and hit[0]() is a:
            return hit[1]
        fp = matrix_fingerprint(a)
        try:
            if len(self._fp_memo) > 64:        # drop dead refs, stay small
                self._fp_memo = {k: v for k, v in self._fp_memo.items()
                                 if v[0]() is not None}
            self._fp_memo[key] = (weakref.ref(a), fp)
        except TypeError:                      # un-weakref-able input
            pass
        return fp

    def _group_key(self, req: SolveRequest):
        return (req.cache_key, self._fingerprints[req.request_id],
                req.method)

    def drain(self) -> dict[int, tuple[Any, SolveInfo]]:
        """Solve everything queued; returns ``{request_id: (x, info)}``.

        Exception-safe: if a batch fails (e.g. a client submitted a
        non-SPD matrix and the factorization raised), the exception
        propagates, but no other work is lost — results completed
        before the failure are stashed and returned by the NEXT drain,
        requests not yet attempted go back on the queue in submission
        order, and the failing batch's requests land in ``self.failed``
        for the caller to report or resubmit (they are NOT re-queued:
        retrying a deterministically failing batch would wedge every
        subsequent drain).
        """
        with self._lock:
            queue, self._queue = self._queue, []
            results, self._stashed = self._stashed, {}
            self.failed = []
        groups: list[list[SolveRequest]] = []
        index: dict[Any, int] = {}
        for req in queue:                       # FIFO by first arrival
            key = self._group_key(req)
            if key in index:
                groups[index[key]].append(req)
            else:
                index[key] = len(groups)
                groups.append([req])
        in_flight: list[SolveRequest] = []
        try:
            for members in groups:
                for chunk in self._chunks(members):
                    start = time.monotonic()
                    in_flight = chunk          # blamed if the solve raises
                    xs, infos = self._solve_chunk(chunk)
                    in_flight = []
                    for req, x, info in zip(chunk, xs, infos):
                        results[req.request_id] = (
                            x, self._stamp(info, req, start))
                        self._fingerprints.pop(req.request_id, None)
        except BaseException:
            # only a chunk whose solve actually raised is abandoned; an
            # interrupt between chunks re-queues everything unprocessed
            with self._lock:
                self.failed = list(in_flight)
                dropped = {r.request_id for r in in_flight}
                for rid in dropped:
                    self._fingerprints.pop(rid, None)
                self._stashed = results
                self._queue = [r for r in queue
                               if r.request_id not in results
                               and r.request_id not in dropped] + self._queue
            raise
        return results

    def _chunks(self, members: list[SolveRequest]):
        """Split a group so no refine call exceeds ``max_batch`` columns."""
        chunk: list[SolveRequest] = []
        width = 0
        for req in members:
            if chunk and width + req.n_cols > self.max_batch:
                yield chunk
                chunk, width = [], 0
            chunk.append(req)
            width += req.n_cols
        if chunk:
            yield chunk
