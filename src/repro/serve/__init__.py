"""The curated serving surface — import serving names from HERE.

``repro.serve`` is the public API of the serving stack; the submodules
(``engine``, ``scheduler``, ``frontend``, ``metrics``, ``options``) are
implementation layout and may move between PRs. The audit lint pack
enforces this boundary for in-repo callers (rule ``serve-public-surface``,
src/repro/audit/lint.py).

The stack, bottom-up:

* :class:`SolverEngine` — accuracy-targeted SPD solves over a
  fingerprint-guarded factor cache (``solve`` / ``solve_batched``).
* :class:`BatchScheduler` — cross-request batching: windowed drains or
  continuous batching (``continuous=True``; mid-flight column
  join/retire). Raises :class:`SchedulerOverload` on admission-control
  rejection.
* :class:`ServeFrontend` — tiered load shedding (degrade digits before
  rejecting) on top of the scheduler.
* :class:`SolveOptions` — the one per-request policy object every entry
  point accepts; :class:`SolveInfo` the per-request result metadata.
* :class:`MetricsTracker` — the protocol a pluggable metrics sink
  implements; :class:`InMemoryMetrics` / :class:`NullMetrics` the
  bundled implementations.

``prefill_step`` / ``serve_step`` / ``generate`` are the model-serving
side (decode-shape dry runs, examples/serve.py).
"""
from repro.serve.engine import (SolveInfo, SolverEngine, generate,
                                matrix_fingerprint, prefill_step, serve_step)
from repro.serve.frontend import ServeFrontend
from repro.serve.metrics import InMemoryMetrics, MetricsTracker, NullMetrics
from repro.serve.options import SolveOptions
from repro.serve.scheduler import (BatchScheduler, SchedulerOverload,
                                   SolveRequest)

__all__ = [
    "BatchScheduler",
    "InMemoryMetrics",
    "MetricsTracker",
    "NullMetrics",
    "SchedulerOverload",
    "ServeFrontend",
    "SolveInfo",
    "SolveOptions",
    "SolveRequest",
    "SolverEngine",
    "generate",
    "matrix_fingerprint",
    "prefill_step",
    "serve_step",
]
