from repro.serve.engine import generate, prefill_step, serve_step  # noqa: F401
