from repro.serve.engine import (SolveInfo, SolverEngine,  # noqa: F401
                                generate, matrix_fingerprint, prefill_step,
                                serve_step)
from repro.serve.scheduler import (BatchScheduler,  # noqa: F401
                                   SchedulerOverload, SolveRequest)
