from repro.serve.engine import (SolveInfo, SolverEngine,  # noqa: F401
                                generate, prefill_step, serve_step)
