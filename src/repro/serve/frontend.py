"""Tiered-load-shedding service front for the batch scheduler.

The raw :class:`~repro.serve.scheduler.BatchScheduler` has one
backpressure lever: admission control raises
:class:`~repro.serve.scheduler.SchedulerOverload` and the client gets
nothing. Production serving wants a *graduated* response — the paper's
whole pitch is that accuracy is a knob, so the first thing to give up
under load is DIGITS, not availability. :class:`ServeFrontend` keys
three tiers off the scheduler's queue depth (pending RHS columns, via
:meth:`~repro.serve.scheduler.BatchScheduler.pending_cols`):

========  =========================  =====================================
tier      depth                      behavior
========  =========================  =====================================
0         ``< soft_pending``         admit as requested
1         ``[soft_pending,           admit with ``target_digits`` capped
          hard_pending)``            at ``degraded_digits`` (cheaper:
                                     fewer refinement sweeps per column);
                                     ``SolveInfo.shed_tier == 1``
2         ``>= hard_pending``        reject with ``SchedulerOverload``
========  =========================  =====================================

Tier 1 is load shedding a refinement server can uniquely afford: a
degraded request still returns a correct solve, just to fewer digits —
each dropped digit saves O(n^2 k) sweep work — and ``shed_tier`` in its
:class:`~repro.serve.engine.SolveInfo` tells the client to resubmit
later if full accuracy matters. Every decision is counted on the
metrics tracker (``frontend.shed`` labelled by tier).
"""
from __future__ import annotations

import dataclasses

from repro.serve.metrics import MetricsTracker
from repro.serve.options import SolveOptions, resolve_options
from repro.serve.scheduler import BatchScheduler, SchedulerOverload


class ServeFrontend:
    """Deadline- and load-aware admission front over a scheduler.

    ``soft_pending`` / ``hard_pending`` are queue depths in RHS columns
    (the unit the scheduler batches in); ``degraded_digits`` is the
    accuracy floor tier 1 degrades to — requests already asking for
    less keep their own target. ``metrics`` defaults to the scheduler's
    tracker, so one injected sink observes engine, scheduler and
    frontend together.
    """

    def __init__(self, scheduler: BatchScheduler, *,
                 soft_pending: int, hard_pending: int,
                 degraded_digits: float = 4.0,
                 metrics: MetricsTracker | None = None):
        assert 0 < soft_pending <= hard_pending, (soft_pending, hard_pending)
        self.scheduler = scheduler
        self.soft_pending = soft_pending
        self.hard_pending = hard_pending
        self.degraded_digits = degraded_digits
        self.metrics: MetricsTracker = (metrics if metrics is not None
                                        else scheduler.metrics)

    def shed_tier(self) -> int:
        """The tier a submission arriving NOW would be assigned."""
        depth = self.scheduler.pending_cols()
        if depth >= self.hard_pending:
            return 2
        return 1 if depth >= self.soft_pending else 0

    def submit(self, a, b, options: SolveOptions | None = None, **kw):
        """Admit through the shedding tiers; returns the scheduler's
        Future. Tier 2 raises :class:`SchedulerOverload`; tier 1 admits
        with the accuracy target capped at ``degraded_digits`` and
        ``SolveInfo.shed_tier`` set so the client can tell. Deprecated
        kwarg aliases as on the scheduler entry points.
        """
        opts = resolve_options(options, kw, caller="ServeFrontend.submit")
        tier = self.shed_tier()
        self.metrics.inc("frontend.requests")
        if tier == 2:
            self.metrics.inc("frontend.shed", tier=2)
            raise SchedulerOverload(
                f"{self.scheduler.pending_cols()} columns pending "
                f"(hard_pending={self.hard_pending})")
        if tier == 1:
            self.metrics.inc("frontend.shed", tier=1)
            opts = dataclasses.replace(
                opts, shed_tier=1,
                target_digits=min(float(opts.target_digits),
                                  self.degraded_digits))
        return self.scheduler.submit_async(a, b, opts)
