"""Serving engine: batched prefill + decode with sharded caches.

``prefill_step`` / ``serve_step`` are the two functions the decode_* and
long_* dry-run cells lower (assignment: decode shapes lower serve_step —
one new token against a seq_len KV cache — not train_step).

``generate`` is the host-side loop used by examples/serve.py: prefill a
prompt batch, then greedy/temperature decode with a step-jitted
serve_step. Continuous batching at cluster scale would slot new requests
into free cache rows between steps; the cache layout (batch-major,
position-indexed) is chosen so that insertion is a dynamic_update_slice
per row (documented seam, not exercised here).

``SolverEngine`` is the linear-algebra side of serving: SPD solve
requests carry a per-request ACCURACY TARGET (decimal digits of relative
residual) instead of naming a precision ladder. The engine always
factorizes in the cheapest ladder and spends iterative-refinement sweeps
— O(n^2) each — to reach the requested digits, caching factors across
requests that share a matrix.
"""
from __future__ import annotations

import collections
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocked import diag_tri_inv
from repro.core.precision import PAPER_CONFIGS, PrecisionConfig
from repro.core.refine import RefineConfig, RefineResult
from repro.core.solve import cholesky_padded, refine_solve
from repro.models import transformer as T
from repro.models.common import ModelConfig, NO_SHARD, Sharder


def prefill_step(params, batch, cfg: ModelConfig,
                 sharder: Sharder = NO_SHARD):
    """Full-sequence forward; returns (last_logits, caches)."""
    logits, _, caches = T.forward(params, batch, cfg, sharder,
                                  mode="prefill", last_only=True)
    return logits[:, -1], caches


def serve_step(params, caches, tokens, pos, cfg: ModelConfig,
               sharder: Sharder = NO_SHARD, extra=None):
    """One decode step. tokens: [B, 1] (audio: [B, 1, n_codebooks]);
    pos: scalar int32 absolute position. Returns (logits, new_caches)."""
    batch = {"tokens": tokens}
    if extra:
        batch.update(extra)
    logits, _, caches = T.forward(params, batch, cfg, sharder,
                                  mode="decode", caches=caches, pos=pos)
    return logits[:, 0], caches


def generate(params, prompt_batch, cfg: ModelConfig, *, n_tokens: int,
             sharder: Sharder = NO_SHARD, temperature: float = 0.0,
             rng=None, max_len: int | None = None):
    """Greedy / sampled generation (host loop, jitted step)."""
    S = prompt_batch["tokens"].shape[1]
    max_len = max_len or (S + n_tokens)
    last, caches = prefill_step(params, prompt_batch, cfg, sharder)
    caches = T.pad_caches(caches, max_len)

    step = jax.jit(functools.partial(serve_step, cfg=cfg, sharder=sharder))

    outs = []
    tok = _pick(last, cfg, temperature, rng, 0)
    outs.append(tok)
    for i in range(1, n_tokens):
        logits, caches = step(params, caches, tok, jnp.int32(S + i - 1))
        tok = _pick(logits, cfg, temperature, rng, i)
        outs.append(tok)
    return jnp.concatenate(outs, axis=1)


# ---------------------------------------------------------------------------
# accuracy-targeted SPD solve serving
# ---------------------------------------------------------------------------
def matrix_fingerprint(a, samples: int = 8):
    """Cheap identity check for a cached factor: shape, dtype, trace and
    a strided sample of the diagonal and first row.

    O(n) device work and a ~2*samples-float transfer — negligible next
    to the O(n^3) factorization it guards. Collisions require two
    matrices agreeing on every sampled entry AND the trace, which no
    real request stream produces by accident; the failure it prevents
    (a reused ``cache_key`` silently solving against a stale factor) was
    an actual correctness bug.
    """
    a = jnp.asarray(a)
    n = a.shape[0]
    stride = max(1, n // samples)
    probe = jnp.concatenate([
        jnp.diagonal(a)[::stride].ravel(),
        a[0, ::stride].ravel(),
        jnp.trace(a)[None],
    ]).astype(jnp.float32)
    return (a.shape, str(a.dtype), np.asarray(probe).tobytes())


@dataclasses.dataclass
class SolveInfo:
    """Per-request serving metadata returned next to the solution."""

    ladder: str                 # PAPER_CONFIGS key actually used
    method: str                 # "ir" | "gmres"
    sweeps: int                 # refinement sweeps spent
    residual: float             # achieved relative residual
    converged: bool
    target_digits: float        # digits actually targeted (post-clamp)
    factor_cached: bool         # True if the factor was reused
    batch_size: int = 1         # requests sharing this refine call
    batch_index: int = 0        # this request's slot in the batch


class SolverEngine:
    """Serve SPD solves against a per-request accuracy target.

    Clients ask for *digits* (``-log10`` of the relative residual), not a
    precision ladder: the engine always factorizes in its cheap default
    ladder and buys accuracy with iterative-refinement sweeps (O(n^2)
    each) instead of higher-precision factorizations (O(n^3)). Targets
    beyond the residual precision's floor are clamped (f32 residuals cap
    at ~7 digits; enable x64 for more — the engine picks the widest
    enabled dtype automatically).

    Factors are cached under a caller-provided ``cache_key`` so request
    streams that share a matrix (GP hyperparameter sweeps, K-FAC-style
    repeated solves) pay the O(n^3) factorization once. Each cached
    factor carries a :func:`matrix_fingerprint` of the matrix it was
    computed from — a reused key with a DIFFERENT matrix forces
    refactorization instead of silently solving against a stale factor
    — and the cache is LRU-bounded by ``max_cached_factors`` so it
    cannot grow without limit under production traffic.

    :meth:`solve_batched` is the cross-request entry point the
    :class:`~repro.serve.scheduler.BatchScheduler` uses: it stacks many
    RHS sharing a factor into ONE multi-RHS refine call with per-column
    accuracy targets, so easy requests stop sweeping while hard
    neighbors continue.
    """

    #: digits attainable by the residual precision (with ~1 digit margin)
    _FLOOR_DIGITS = {"f32": 7.0, "f64": 14.0}

    def __init__(self, ladder: str | PrecisionConfig = "bf16_f32", *,
                 max_sweeps: int = 10, gmres_restart: int = 16,
                 max_cached_factors: int = 16):
        if isinstance(ladder, str):
            self.ladder_name = ladder
            self.cfg = PAPER_CONFIGS[ladder]
        else:
            self.ladder_name = ladder.describe()
            self.cfg = ladder
        self.max_sweeps = max_sweeps
        self.gmres_restart = gmres_restart
        assert max_cached_factors >= 1, max_cached_factors
        self.max_cached_factors = max_cached_factors
        #: cache_key -> (fingerprint, padded factor, diag-tile inverses),
        #: most-recently-used last
        self._factors: collections.OrderedDict = collections.OrderedDict()

    def _clamp(self, target_digits: float) -> float:
        rname = "f64" if jax.config.jax_enable_x64 else "f32"
        return min(float(target_digits), self._FLOOR_DIGITS[rname])

    def _factorize(self, a):
        """Padded factor + blocked-engine diagonal-tile inverses.

        The factor is kept in its leaf-padded form (``pad_factor``
        semantics) so non-multiple-of-leaf solves skip re-padding on
        every request, and ``linvs`` lets every refinement sweep's pair
        of triangular solves reuse the one-off leaf inversions.
        """
        l = cholesky_padded(a, self.cfg)
        linvs = (diag_tri_inv(l, self.cfg)
                 if self.cfg.engine == "blocked" else None)
        return l, linvs

    def factor(self, a, cache_key=None, *, fingerprint=None):
        """Factorize (or fetch the cached factor for) ``a``.

        Returns ``(l, linvs, cached)`` — the leaf-padded factor, the
        cached diagonal-tile inverses (None for the tree engine) and a
        cache-hit flag. A cache hit is only served when the stored
        fingerprint matches ``a`` — a reused key with new matrix data
        refactorizes (and replaces the stale entry) rather than
        returning a factor of some other matrix. Insertions evict
        least-recently-used entries beyond ``max_cached_factors``.
        ``fingerprint`` lets callers that already fingerprinted ``a``
        (the scheduler does, at submit time) skip the redundant O(n)
        device round-trip.
        """
        if cache_key is None:
            l, linvs = self._factorize(a)
            return l, linvs, False
        fp = fingerprint if fingerprint is not None else matrix_fingerprint(a)
        hit = self._factors.get(cache_key)
        if hit is not None and hit[0] == fp:
            self._factors.move_to_end(cache_key)
            return hit[1], hit[2], True
        l, linvs = self._factorize(a)
        self._factors[cache_key] = (fp, l, linvs)
        self._factors.move_to_end(cache_key)
        while len(self._factors) > self.max_cached_factors:
            self._factors.popitem(last=False)
        return l, linvs, False

    def evict(self, cache_key):
        self._factors.pop(cache_key, None)

    def cached_keys(self):
        """Cache keys currently held, least-recently-used first."""
        return list(self._factors)

    def solve(self, a, b, *, target_digits: float = 6.0,
              method: str = "ir", cache_key=None):
        """Solve A x = b to ``target_digits``; returns ``(x, SolveInfo)``.

        ``method="gmres"`` requests GMRES-IR for ill-conditioned systems
        where classic IR stalls. ``b`` may be (n,) or (n, k); for a
        multi-RHS ``b`` the SolveInfo aggregates across columns (max
        sweeps/residual, all-converged).
        """
        xs, infos = self.solve_batched(a, [b], target_digits=target_digits,
                                       method=method, cache_key=cache_key)
        return xs[0], infos[0]

    def solve_batched(self, a, bs, *, target_digits=6.0,
                      method: str = "ir", cache_key=None,
                      fingerprint=None):
        """Solve A x_i = b_i for a batch of RHS sharing one factor.

        ``bs`` is a sequence of (n,) vectors and/or (n, k_i) blocks (one
        per request); ``target_digits`` is a scalar or a per-request
        sequence. All RHS are stacked into a single multi-RHS refine
        call whose per-column tolerances encode each request's target,
        so converged columns freeze while slow ones keep sweeping.
        Returns ``(xs, infos)`` aligned with ``bs``; each request's x
        keeps its input arity (vector in, vector out) in the residual
        precision.
        """
        bs = [jnp.asarray(b) for b in bs]
        assert bs, "solve_batched needs at least one RHS"
        n = bs[0].shape[0]
        for b in bs:
            assert b.ndim in (1, 2) and b.shape[0] == n, b.shape
        cols = [1 if b.ndim == 1 else b.shape[1] for b in bs]
        if np.isscalar(target_digits):
            target_digits = [target_digits] * len(bs)
        assert len(target_digits) == len(bs), (len(target_digits), len(bs))
        digits = [self._clamp(d) for d in target_digits]
        col_tol = np.repeat([10.0 ** -d for d in digits], cols)
        rcfg = RefineConfig(max_sweeps=self.max_sweeps,
                            tol=float(col_tol.min()), method=method,
                            gmres_restart=self.gmres_restart)
        l, linvs, cached = self.factor(a, cache_key, fingerprint=fingerprint)
        bmat = jnp.concatenate(
            [b[:, None] if b.ndim == 1 else b for b in bs], axis=1)
        res: RefineResult = refine_solve(a, bmat, self.cfg, refine=rcfg,
                                         l=l, col_tol=jnp.asarray(col_tol),
                                         linvs=linvs)
        sweeps = np.atleast_1d(np.asarray(res.iterations))
        resid = np.atleast_1d(np.asarray(res.residual))
        conv = np.atleast_1d(np.asarray(res.converged))
        xs, infos = [], []
        off = 0
        for i, (b, k) in enumerate(zip(bs, cols)):
            x = res.x[:, off:off + k]
            xs.append(x[:, 0] if b.ndim == 1 else x)
            sl = slice(off, off + k)
            infos.append(SolveInfo(
                ladder=self.ladder_name, method=method,
                sweeps=int(sweeps[sl].max()),
                residual=float(resid[sl].max()),
                converged=bool(conv[sl].all()),
                target_digits=digits[i], factor_cached=cached,
                batch_size=len(bs), batch_index=i))
            off += k
        return xs, infos


def _pick(logits, cfg: ModelConfig, temperature, rng, i):
    """logits: [B, V] (audio: [B, n_cb, V]) -> next token [B, 1, ...]."""
    if temperature > 0:
        assert rng is not None
        k = jax.random.fold_in(rng, i)
        tok = jax.random.categorical(k, logits / temperature, axis=-1)
    else:
        tok = jnp.argmax(logits, axis=-1)
    if cfg.family == "audio":
        return tok[:, None, :]          # [B, 1, n_cb]
    return tok[:, None]
