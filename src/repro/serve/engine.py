"""Serving engine: batched prefill + decode with sharded caches.

``prefill_step`` / ``serve_step`` are the two functions the decode_* and
long_* dry-run cells lower (assignment: decode shapes lower serve_step —
one new token against a seq_len KV cache — not train_step).

``generate`` is the host-side loop used by examples/serve.py: prefill a
prompt batch, then greedy/temperature decode with a step-jitted
serve_step. Continuous batching at cluster scale would slot new requests
into free cache rows between steps; the cache layout (batch-major,
position-indexed) is chosen so that insertion is a dynamic_update_slice
per row (documented seam, not exercised here).

``SolverEngine`` is the linear-algebra side of serving: SPD solve
requests carry a per-request ACCURACY TARGET (decimal digits of relative
residual) instead of naming a precision ladder. The engine always
factorizes in the cheapest ladder and spends iterative-refinement sweeps
— O(n^2) each — to reach the requested digits, caching factors across
requests that share a matrix.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.precision import PAPER_CONFIGS, PrecisionConfig
from repro.core.refine import RefineConfig, RefineResult
from repro.core.solve import cholesky, refine_solve
from repro.models import transformer as T
from repro.models.common import ModelConfig, NO_SHARD, Sharder


def prefill_step(params, batch, cfg: ModelConfig,
                 sharder: Sharder = NO_SHARD):
    """Full-sequence forward; returns (last_logits, caches)."""
    logits, _, caches = T.forward(params, batch, cfg, sharder,
                                  mode="prefill", last_only=True)
    return logits[:, -1], caches


def serve_step(params, caches, tokens, pos, cfg: ModelConfig,
               sharder: Sharder = NO_SHARD, extra=None):
    """One decode step. tokens: [B, 1] (audio: [B, 1, n_codebooks]);
    pos: scalar int32 absolute position. Returns (logits, new_caches)."""
    batch = {"tokens": tokens}
    if extra:
        batch.update(extra)
    logits, _, caches = T.forward(params, batch, cfg, sharder,
                                  mode="decode", caches=caches, pos=pos)
    return logits[:, 0], caches


def generate(params, prompt_batch, cfg: ModelConfig, *, n_tokens: int,
             sharder: Sharder = NO_SHARD, temperature: float = 0.0,
             rng=None, max_len: int | None = None):
    """Greedy / sampled generation (host loop, jitted step)."""
    S = prompt_batch["tokens"].shape[1]
    max_len = max_len or (S + n_tokens)
    last, caches = prefill_step(params, prompt_batch, cfg, sharder)
    caches = T.pad_caches(caches, max_len)

    step = jax.jit(functools.partial(serve_step, cfg=cfg, sharder=sharder))

    outs = []
    tok = _pick(last, cfg, temperature, rng, 0)
    outs.append(tok)
    for i in range(1, n_tokens):
        logits, caches = step(params, caches, tok, jnp.int32(S + i - 1))
        tok = _pick(logits, cfg, temperature, rng, i)
        outs.append(tok)
    return jnp.concatenate(outs, axis=1)


# ---------------------------------------------------------------------------
# accuracy-targeted SPD solve serving
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class SolveInfo:
    """Per-request serving metadata returned next to the solution."""

    ladder: str                 # PAPER_CONFIGS key actually used
    method: str                 # "ir" | "gmres"
    sweeps: int                 # refinement sweeps spent
    residual: float             # achieved relative residual
    converged: bool
    target_digits: float        # digits actually targeted (post-clamp)
    factor_cached: bool         # True if the factor was reused


class SolverEngine:
    """Serve SPD solves against a per-request accuracy target.

    Clients ask for *digits* (``-log10`` of the relative residual), not a
    precision ladder: the engine always factorizes in its cheap default
    ladder and buys accuracy with iterative-refinement sweeps (O(n^2)
    each) instead of higher-precision factorizations (O(n^3)). Targets
    beyond the residual precision's floor are clamped (f32 residuals cap
    at ~7 digits; enable x64 for more — the engine picks the widest
    enabled dtype automatically).

    Factors are cached under a caller-provided ``cache_key`` so request
    streams that share a matrix (GP hyperparameter sweeps, K-FAC-style
    repeated solves) pay the O(n^3) factorization once.
    """

    #: digits attainable by the residual precision (with ~1 digit margin)
    _FLOOR_DIGITS = {"f32": 7.0, "f64": 14.0}

    def __init__(self, ladder: str | PrecisionConfig = "bf16_f32", *,
                 max_sweeps: int = 10, gmres_restart: int = 16):
        if isinstance(ladder, str):
            self.ladder_name = ladder
            self.cfg = PAPER_CONFIGS[ladder]
        else:
            self.ladder_name = ladder.describe()
            self.cfg = ladder
        self.max_sweeps = max_sweeps
        self.gmres_restart = gmres_restart
        self._factors: dict = {}

    def _clamp(self, target_digits: float) -> float:
        rname = "f64" if jax.config.jax_enable_x64 else "f32"
        return min(float(target_digits), self._FLOOR_DIGITS[rname])

    def factor(self, a, cache_key=None):
        """Factorize (or fetch the cached factor for) ``a``."""
        if cache_key is not None and cache_key in self._factors:
            return self._factors[cache_key], True
        l = cholesky(a, self.cfg)
        if cache_key is not None:
            self._factors[cache_key] = l
        return l, False

    def evict(self, cache_key):
        self._factors.pop(cache_key, None)

    def solve(self, a, b, *, target_digits: float = 6.0,
              method: str = "ir", cache_key=None):
        """Solve A x = b to ``target_digits``; returns ``(x, SolveInfo)``.

        ``method="gmres"`` requests GMRES-IR for ill-conditioned systems
        where classic IR stalls.
        """
        digits = self._clamp(target_digits)
        rcfg = RefineConfig(max_sweeps=self.max_sweeps,
                            tol=10.0 ** -digits, method=method,
                            gmres_restart=self.gmres_restart)
        l, cached = self.factor(a, cache_key)
        res: RefineResult = refine_solve(a, b, self.cfg, refine=rcfg, l=l)
        info = SolveInfo(ladder=self.ladder_name, method=method,
                         sweeps=int(res.iterations),
                         residual=float(res.residual),
                         converged=bool(res.converged),
                         target_digits=digits, factor_cached=cached)
        return res.x, info


def _pick(logits, cfg: ModelConfig, temperature, rng, i):
    """logits: [B, V] (audio: [B, n_cb, V]) -> next token [B, 1, ...]."""
    if temperature > 0:
        assert rng is not None
        k = jax.random.fold_in(rng, i)
        tok = jax.random.categorical(k, logits / temperature, axis=-1)
    else:
        tok = jnp.argmax(logits, axis=-1)
    if cfg.family == "audio":
        return tok[:, None, :]          # [B, 1, n_cb]
    return tok[:, None]
