"""Serving engine: batched prefill + decode with sharded caches.

``prefill_step`` / ``serve_step`` are the two functions the decode_* and
long_* dry-run cells lower (assignment: decode shapes lower serve_step —
one new token against a seq_len KV cache — not train_step).

``generate`` is the host-side loop used by examples/serve.py: prefill a
prompt batch, then greedy/temperature decode with a step-jitted
serve_step. Continuous batching at cluster scale would slot new requests
into free cache rows between steps; the cache layout (batch-major,
position-indexed) is chosen so that insertion is a dynamic_update_slice
per row (documented seam, not exercised here).

``SolverEngine`` is the linear-algebra side of serving: SPD solve
requests carry a per-request ACCURACY TARGET (decimal digits of relative
residual) instead of naming a precision ladder. The engine always
factorizes in the cheapest ladder and spends iterative-refinement sweeps
— O(n^2) each — to reach the requested digits, caching factors across
requests that share a matrix.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import threading

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.core.blocked import diag_tri_inv
from repro.core.distributed import dist_cholesky, dist_cholesky_solve
from repro.core.precision import PAPER_CONFIGS, PrecisionConfig
from repro.core.refine import (RefineConfig, RefineResult, RefineStepper,
                               gmres_operator, refine_operator, scaled_solve)
from repro.core.solve import cholesky_padded, refine_solve, solve_factored
from repro.kernels import ops
from repro.models import transformer as T
from repro.models.common import ModelConfig, NO_SHARD, Sharder
from repro.serve.metrics import MetricsTracker, NullMetrics
from repro.serve.options import SolveOptions, resolve_options


def prefill_step(params, batch, cfg: ModelConfig,
                 sharder: Sharder = NO_SHARD):
    """Full-sequence forward; returns (last_logits, caches)."""
    logits, _, caches = T.forward(params, batch, cfg, sharder,
                                  mode="prefill", last_only=True)
    return logits[:, -1], caches


def serve_step(params, caches, tokens, pos, cfg: ModelConfig,
               sharder: Sharder = NO_SHARD, extra=None):
    """One decode step. tokens: [B, 1] (audio: [B, 1, n_codebooks]);
    pos: scalar int32 absolute position. Returns (logits, new_caches)."""
    batch = {"tokens": tokens}
    if extra:
        batch.update(extra)
    logits, _, caches = T.forward(params, batch, cfg, sharder,
                                  mode="decode", caches=caches, pos=pos)
    return logits[:, 0], caches


def generate(params, prompt_batch, cfg: ModelConfig, *, n_tokens: int,
             sharder: Sharder = NO_SHARD, temperature: float = 0.0,
             rng=None, max_len: int | None = None):
    """Greedy / sampled generation (host loop, jitted step)."""
    S = prompt_batch["tokens"].shape[1]
    max_len = max_len or (S + n_tokens)
    last, caches = prefill_step(params, prompt_batch, cfg, sharder)
    caches = T.pad_caches(caches, max_len)

    step = jax.jit(functools.partial(serve_step, cfg=cfg, sharder=sharder))

    outs = []
    tok = _pick(last, cfg, temperature, rng, 0)
    outs.append(tok)
    for i in range(1, n_tokens):
        logits, caches = step(params, caches, tok, jnp.int32(S + i - 1))
        tok = _pick(logits, cfg, temperature, rng, i)
        outs.append(tok)
    return jnp.concatenate(outs, axis=1)


# ---------------------------------------------------------------------------
# accuracy-targeted SPD solve serving
# ---------------------------------------------------------------------------
def matrix_fingerprint(a, samples: int = 8):
    """Cheap identity check for a cached factor: shape, dtype, trace and
    a strided sample of the diagonal and first row.

    O(n) device work and a ~2*samples-float transfer — negligible next
    to the O(n^3) factorization it guards. Collisions require two
    matrices agreeing on every sampled entry AND the trace, which no
    real request stream produces by accident; the failure it prevents
    (a reused ``cache_key`` silently solving against a stale factor) was
    an actual correctness bug.
    """
    a = jnp.asarray(a)
    n = a.shape[0]
    stride = max(1, n // samples)
    probe = jnp.concatenate([
        jnp.diagonal(a)[::stride].ravel(),
        a[0, ::stride].ravel(),
        jnp.trace(a)[None],
    ]).astype(jnp.float32)
    return (a.shape, str(a.dtype), np.asarray(probe).tobytes())


def _strip_history(h):
    """Nan-padded ``[sweeps+1, k]`` history -> per-column float tuples.

    Drops the window loop's nan padding (sweeps a column never ran /
    ran frozen) so the windowed and continuous paths hand back the
    same trajectory for the same column.
    """
    return tuple(tuple(float(v) for v in col[~np.isnan(col)])
                 for col in h.T)


@dataclasses.dataclass
class SolveInfo:
    """Per-request serving metadata returned next to the solution.

    ``queue_ms``/``shed_tier``/``deadline_expired`` are stamped by the
    serving layer (scheduler/frontend); direct engine calls leave their
    defaults.  ``history`` is the per-column relative-residual
    trajectory — ``history[j][0]`` the pre-refinement residual of this
    request's column ``j``, then one entry per sweep that column
    actually ran (the window loop's nan padding is stripped, so the
    continuous and windowed paths report identical histories).
    """

    ladder: str                 # PAPER_CONFIGS key actually used
    method: str                 # "ir" | "gmres"
    sweeps: int                 # refinement sweeps spent
    residual: float             # achieved relative residual
    converged: bool
    target_digits: float        # digits actually targeted (post-clamp)
    factor_cached: bool         # True if the factor was reused
    batch_size: int = 1         # requests sharing this refine call
    batch_index: int = 0        # this request's slot in the batch
    distributed: bool = False   # factor/solves ran on the engine's mesh
    queue_ms: float = 0.0       # submit -> solve-start latency
    shed_tier: int = 0          # 0 = as requested, 1 = degraded target
    deadline_expired: bool = False  # retired at its deadline, best-so-far
    history: tuple = ()         # per-column residual trajectories


class SolverEngine:
    """Serve SPD solves against a per-request accuracy target.

    Clients ask for *digits* (``-log10`` of the relative residual), not a
    precision ladder: the engine always factorizes in its cheap default
    ladder and buys accuracy with iterative-refinement sweeps (O(n^2)
    each) instead of higher-precision factorizations (O(n^3)). Targets
    beyond the residual precision's floor are clamped (f32 residuals cap
    at ~7 digits; enable x64 for more — the engine picks the widest
    enabled dtype automatically).

    Factors are cached under a caller-provided ``cache_key`` so request
    streams that share a matrix (GP hyperparameter sweeps, K-FAC-style
    repeated solves) pay the O(n^3) factorization once. Each cached
    factor carries a :func:`matrix_fingerprint` of the matrix it was
    computed from — a reused key with a DIFFERENT matrix forces
    refactorization instead of silently solving against a stale factor
    — and the cache is LRU-bounded by ``max_cached_factors`` so it
    cannot grow without limit under production traffic.

    :meth:`solve_batched` is the cross-request entry point the
    :class:`~repro.serve.scheduler.BatchScheduler` uses: it stacks many
    RHS sharing a factor into ONE multi-RHS refine call with per-column
    accuracy targets, so easy requests stop sweeping while hard
    neighbors continue.

    **Multi-device mode** (docs/SERVING.md, "Multi-device mode"): pass
    ``mesh=`` to route factorizations of matrices at or above
    ``dist_threshold`` (whose size divides the mesh axis times the leaf)
    through the distributed block-panel solver
    (:func:`repro.core.distributed.dist_cholesky`), with every
    refinement sweep's correction solve running distributed too
    (:func:`~repro.core.distributed.dist_cholesky_solve`). The factor
    cache then stores the SHARDED factor per fingerprint — cache hits
    reuse device-resident shards, no re-gather. Smaller or non-divisible
    matrices fall back to the single-device path; ``SolveInfo
    .distributed`` records which path served each request.
    """

    #: digits attainable by the residual precision (with ~1 digit margin)
    _FLOOR_DIGITS = {"f32": 7.0, "f64": 14.0}

    def __init__(self, ladder: str | PrecisionConfig = "bf16_f32", *,
                 max_sweeps: int = 10, gmres_restart: int = 16,
                 max_cached_factors: int = 16, mesh=None,
                 dist_threshold: int | None = None,
                 dist_axis: str = "model",
                 dist_compress: bool | None = None, tuning_db=None,
                 metrics: MetricsTracker | None = None):
        if isinstance(ladder, str):
            self.ladder_name = ladder
            self.cfg = PAPER_CONFIGS[ladder]
        else:
            self.ladder_name = ladder.describe()
            self.cfg = ladder
        self.max_sweeps = max_sweeps
        self.gmres_restart = gmres_restart
        assert max_cached_factors >= 1, max_cached_factors
        self.max_cached_factors = max_cached_factors
        self.mesh = mesh
        #: None = consult the tuning DB per problem size (docs/TUNING.md),
        #: falling back to the pre-tuner 2048; an int pins the threshold
        self.dist_threshold = dist_threshold
        self.dist_axis = dist_axis
        #: None = the tuning DB's measured per-size choice; a bool pins it
        self.dist_compress = dist_compress
        #: injected TuningDB (tests); None = the committed per-backend DB
        self._tuning_db = tuning_db
        #: pluggable metrics sink (repro.serve.metrics); shared by the
        #: scheduler/frontend stacked on this engine unless overridden
        self.metrics: MetricsTracker = (metrics if metrics is not None
                                        else NullMetrics())
        if mesh is not None:
            assert dist_axis in mesh.shape, (dist_axis, mesh)
        #: cache_key -> (fingerprint, padded factor, diag-tile inverses),
        #: most-recently-used last; in mesh mode the factor entry is the
        #: block-row-sharded L. Guarded by ``_cache_lock``: the async
        #: scheduler's drain worker shares this cache with direct-call
        #: engine users on other threads.
        self._factors: collections.OrderedDict = collections.OrderedDict()
        #: (cache_key, fingerprint, slots) -> (RefineStepper, base_solve):
        #: a stepper's jitted sweep is cached per factor so re-activating
        #: a continuous group doesn't recompile (same LRU bound)
        self._steppers: collections.OrderedDict = collections.OrderedDict()
        self._cache_lock = threading.RLock()

    def _tuned(self, n: int, nshards: int):
        """Tuning-DB decision for ``(n, ladder, nshards)`` (repro.tune)."""
        from repro import tune
        return tune.decide(n, tune.ladder_key(self.cfg), nshards,
                           db=self._tuning_db)

    def _use_dist(self, n: int) -> bool:
        """True when a size-``n`` solve takes the distributed path.

        Deterministic in ``n`` so :meth:`_factorize` and
        :meth:`solve_batched` always agree on what a cached factor is.
        With ``dist_threshold=None`` the threshold is the tuning
        database's measured value for this size (default 2048).
        """
        if self.mesh is None:
            return False
        nshards = self.mesh.shape[self.dist_axis]
        if n % (nshards * self.cfg.leaf) != 0:
            return False
        thr = self.dist_threshold
        if thr is None:
            thr = self._tuned(n, nshards).dist_threshold
        return n >= thr

    def _cfg_for(self, n: int) -> PrecisionConfig:
        """Per-size engine resolution for ``engine="auto"`` configs.

        Factorization and every later solve against the cached factor
        route through this, so both always agree on the engine (and thus
        on whether ``linvs`` exist for the factor).
        """
        if self.cfg.engine != "auto":
            return self.cfg
        nshards = (self.mesh.shape[self.dist_axis]
                   if self._use_dist(n) else 1)
        return dataclasses.replace(self.cfg,
                                   engine=self._tuned(n, nshards).engine)

    def _clamp(self, target_digits: float) -> float:
        rname = "f64" if jax.config.jax_enable_x64 else "f32"
        return min(float(target_digits), self._FLOOR_DIGITS[rname])

    def _factorize(self, a):
        """Padded factor + blocked-engine diagonal-tile inverses.

        The factor is kept in its leaf-padded form (``pad_factor``
        semantics) so non-multiple-of-leaf solves skip re-padding on
        every request, and ``linvs`` lets every refinement sweep's pair
        of triangular solves reuse the one-off leaf inversions.

        In mesh mode, matrices :meth:`_use_dist` accepts are factorized
        by the distributed block-panel engine instead; the cached factor
        is then the block-row-sharded L (no ``linvs`` — the distributed
        solve inverts its diagonal blocks per shard).
        """
        a = jnp.asarray(a)
        n = a.shape[-1]
        cfg = self._cfg_for(n)
        if self._use_dist(n):
            compress = self.dist_compress
            if compress is None:
                compress = self._tuned(
                    n, self.mesh.shape[self.dist_axis]).compress_comm
            a_sh = jax.device_put(a, NamedSharding(
                self.mesh, PartitionSpec(self.dist_axis, None)))
            l = dist_cholesky(a_sh, self.mesh, cfg,
                              axis=self.dist_axis,
                              compress_comm=compress)
            return l, None
        l = cholesky_padded(a, cfg)
        linvs = (diag_tri_inv(l, cfg)
                 if cfg.engine == "blocked" else None)
        return l, linvs

    def _dist_refine(self, a, bmat, rcfg: RefineConfig, l,
                     col_tol) -> RefineResult:
        """Refinement loop whose correction solves run on the mesh.

        Same contract as :func:`repro.core.solve.refine_solve` (which
        backs the single-device path), but the base solve and every
        sweep's correction go through
        :func:`~repro.core.distributed.dist_cholesky_solve` against the
        sharded factor; residuals form in the residual precision via the
        fused-residual dispatch like the local path.
        """
        rdtype = rcfg.rdtype()
        mesh, axis = self.mesh, self.dist_axis
        cfg = self._cfg_for(a.shape[-1])
        # keep A block-row-sharded for the sweep GEMMs too: the per-sweep
        # matvec/residual is the dominant O(n^2 k) term, and a replicated
        # A would run it on one device
        a_r = jax.device_put(jnp.asarray(a, rdtype), NamedSharding(
            mesh, PartitionSpec(axis, None)))
        b_r = jnp.asarray(bmat, rdtype)

        def base_solve(r):
            x = dist_cholesky_solve(a, r.astype(l.dtype), mesh, cfg,
                                    axis=axis, l=l)
            return x.astype(rdtype)

        def matvec(x):
            return a_r @ x

        def resid(x):
            return ops.residual(a_r, x, b_r, impl=cfg.kernel_impl)

        correct = scaled_solve(base_solve)
        x0 = base_solve(b_r)    # unscaled, like iterative_refine
        run = gmres_operator if rcfg.method == "gmres" else refine_operator
        return run(matvec, correct, b_r, x0, rcfg, resid=resid, tol=col_tol)

    def factor(self, a, cache_key=None, *, fingerprint=None):
        """Factorize (or fetch the cached factor for) ``a``.

        Returns ``(l, linvs, cached)`` — the leaf-padded factor, the
        cached diagonal-tile inverses (None for the tree engine) and a
        cache-hit flag. A cache hit is only served when the stored
        fingerprint matches ``a`` — a reused key with new matrix data
        refactorizes (and replaces the stale entry) rather than
        returning a factor of some other matrix. Insertions evict
        least-recently-used entries beyond ``max_cached_factors``.
        ``fingerprint`` lets callers that already fingerprinted ``a``
        (the scheduler does, at submit time) skip the redundant O(n)
        device round-trip.
        """
        if cache_key is None:
            l, linvs = self._factorize(a)
            self.metrics.inc("engine.factor_cache_miss")
            return l, linvs, False
        fp = fingerprint if fingerprint is not None else matrix_fingerprint(a)
        with self._cache_lock:
            hit = self._factors.get(cache_key)
            if hit is not None and hit[0] == fp:
                self._factors.move_to_end(cache_key)
                self.metrics.inc("engine.factor_cache_hit")
                return hit[1], hit[2], True
        self.metrics.inc("engine.factor_cache_miss")
        l, linvs = self._factorize(a)
        with self._cache_lock:
            self._factors[cache_key] = (fp, l, linvs)
            self._factors.move_to_end(cache_key)
            while len(self._factors) > self.max_cached_factors:
                self._factors.popitem(last=False)
        return l, linvs, False

    def evict(self, cache_key):
        with self._cache_lock:
            self._factors.pop(cache_key, None)
            for k in [k for k in self._steppers if k[0] == cache_key]:
                self._steppers.pop(k)

    def cached_keys(self):
        """Cache keys currently held, least-recently-used first."""
        with self._cache_lock:
            return list(self._factors)

    def solve(self, a, b, options: SolveOptions | None = None, **kw):
        """Solve A x = b per ``options``; returns ``(x, SolveInfo)``.

        ``options.method="gmres"`` requests GMRES-IR for ill-conditioned
        systems where classic IR stalls. ``b`` may be (n,) or (n, k);
        for a multi-RHS ``b`` the SolveInfo aggregates across columns
        (max sweeps/residual, all-converged). Pre-``SolveOptions``
        kwargs (``target_digits=``, ``method=``, ``cache_key=``) keep
        working as deprecated aliases.
        """
        opts = resolve_options(options, kw, caller="SolverEngine.solve")
        xs, infos = self.solve_batched(a, [b], opts)
        return xs[0], infos[0]

    def solve_batched(self, a, bs, options: SolveOptions | None = None,
                      **kw):
        """Solve A x_i = b_i for a batch of RHS sharing one factor.

        ``bs`` is a sequence of (n,) vectors and/or (n, k_i) blocks (one
        per request); ``options.target_digits`` is a scalar or a
        per-request sequence. All RHS are stacked into a single
        multi-RHS refine call whose per-column tolerances encode each
        request's target, so converged columns freeze while slow ones
        keep sweeping. Returns ``(xs, infos)`` aligned with ``bs``; each
        request's x keeps its input arity (vector in, vector out) in the
        residual precision. Deprecated kwarg aliases as in
        :meth:`solve` (plus ``fingerprint=``).
        """
        opts = resolve_options(options, kw,
                               caller="SolverEngine.solve_batched")
        method = opts.method
        bs = [jnp.asarray(b) for b in bs]
        assert bs, "solve_batched needs at least one RHS"
        n = bs[0].shape[0]
        for b in bs:
            assert b.ndim in (1, 2) and b.shape[0] == n, b.shape
        cols = [1 if b.ndim == 1 else b.shape[1] for b in bs]
        target_digits = opts.target_digits
        if np.isscalar(target_digits):
            target_digits = [target_digits] * len(bs)
        assert len(target_digits) == len(bs), (len(target_digits), len(bs))
        digits = [self._clamp(d) for d in target_digits]
        if opts.col_tol is not None:
            col_tol = np.asarray(opts.col_tol, np.float64)
            assert col_tol.shape == (sum(cols),), (col_tol.shape, cols)
        else:
            col_tol = np.repeat([10.0 ** -d for d in digits], cols)
        rcfg = RefineConfig(max_sweeps=self.max_sweeps,
                            tol=float(col_tol.min()), method=method,
                            gmres_restart=self.gmres_restart)
        l, linvs, cached = self.factor(a, opts.cache_key,
                                       fingerprint=opts.fingerprint)
        bmat = jnp.concatenate(
            [b[:, None] if b.ndim == 1 else b for b in bs], axis=1)
        dist = self._use_dist(n)
        if dist:
            res: RefineResult = self._dist_refine(
                a, bmat, rcfg, l, jnp.asarray(col_tol))
        else:
            res = refine_solve(a, bmat, self._cfg_for(n), refine=rcfg,
                               l=l, col_tol=jnp.asarray(col_tol),
                               linvs=linvs)
        sweeps = np.atleast_1d(np.asarray(res.iterations))
        resid = np.atleast_1d(np.asarray(res.residual))
        conv = np.atleast_1d(np.asarray(res.converged))
        hist = np.asarray(res.history)          # [S+1] or [S+1, k]
        if hist.ndim == 1:
            hist = hist[:, None]
        self.metrics.inc("engine.requests", len(bs))
        for s in sweeps:
            self.metrics.observe("engine.sweeps_per_column", int(s))
        xs, infos = [], []
        off = 0
        for i, (b, k) in enumerate(zip(bs, cols)):
            x = res.x[:, off:off + k]
            xs.append(x[:, 0] if b.ndim == 1 else x)
            sl = slice(off, off + k)
            infos.append(SolveInfo(
                ladder=self.ladder_name, method=method,
                sweeps=int(sweeps[sl].max()),
                residual=float(resid[sl].max()),
                converged=bool(conv[sl].all()),
                target_digits=digits[i], factor_cached=cached,
                batch_size=len(bs), batch_index=i, distributed=dist,
                shed_tier=opts.shed_tier,
                history=_strip_history(hist[:, sl])))
            off += k
        return xs, infos

    def continuous_stepper(self, a, *, slots: int, cache_key=None,
                           fingerprint=None):
        """Factor ``a`` (through the cache) and return the continuous-
        batching machinery bound to it: ``(stepper, base_solve, cached)``.

        ``stepper`` is a :class:`repro.core.refine.RefineStepper` over a
        ``slots``-wide RHS block — the re-entrant loop the scheduler's
        continuous worker drives (join/step/retire between sweeps);
        ``base_solve`` computes the initial iterate for joining columns
        (the same unscaled factored solve the windowed path starts
        from, so a column's trajectory is identical in either mode).
        Classic IR only — GMRES-IR's joint Krylov space cannot retire
        columns mid-restart — and single-device only (the scheduler
        windows distributed-path requests).

        The stepper (and its jitted sweep) is cached per
        ``(cache_key, fingerprint, slots)`` next to the factor cache, so
        re-activating a continuous group — the scheduler does this every
        time its block drains and traffic returns — reuses the compiled
        sweep instead of paying an XLA compile per activation.
        """
        a = jnp.asarray(a)
        n = a.shape[-1]
        assert not self._use_dist(n), \
            "continuous batching is single-device; dist requests window"
        fp = fingerprint if fingerprint is not None else matrix_fingerprint(a)
        memo_key = (cache_key, fp, slots)
        with self._cache_lock:
            hit = self._steppers.get(memo_key)
            if hit is not None:
                self._steppers.move_to_end(memo_key)
                return hit[0], hit[1], True
        cfg = self._cfg_for(n)
        l, linvs, cached = self.factor(a, cache_key, fingerprint=fp)
        rcfg = RefineConfig(max_sweeps=self.max_sweeps, method="ir",
                            gmres_restart=self.gmres_restart)
        rdtype = rcfg.rdtype()
        a_r = jnp.asarray(a, rdtype)

        def base_solve(r):
            return solve_factored(l, r.astype(l.dtype), cfg,
                                  linvs=linvs).astype(rdtype)

        def resid(x, b):
            return ops.residual(a_r, x, b, impl=cfg.kernel_impl)

        stepper = RefineStepper(scaled_solve(base_solve), resid,
                                n=n, slots=slots, rcfg=rcfg)
        with self._cache_lock:
            self._steppers[memo_key] = (stepper, base_solve)
            while len(self._steppers) > self.max_cached_factors:
                self._steppers.popitem(last=False)
        return stepper, base_solve, cached


def _pick(logits, cfg: ModelConfig, temperature, rng, i):
    """logits: [B, V] (audio: [B, n_cb, V]) -> next token [B, 1, ...]."""
    if temperature > 0:
        assert rng is not None
        k = jax.random.fold_in(rng, i)
        tok = jax.random.categorical(k, logits / temperature, axis=-1)
    else:
        tok = jnp.argmax(logits, axis=-1)
    if cfg.family == "audio":
        return tok[:, None, :]          # [B, 1, n_cb]
    return tok[:, None]
