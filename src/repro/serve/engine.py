"""Serving engine: batched prefill + decode with sharded caches.

``prefill_step`` / ``serve_step`` are the two functions the decode_* and
long_* dry-run cells lower (assignment: decode shapes lower serve_step —
one new token against a seq_len KV cache — not train_step).

``generate`` is the host-side loop used by examples/serve.py: prefill a
prompt batch, then greedy/temperature decode with a step-jitted
serve_step. Continuous batching at cluster scale would slot new requests
into free cache rows between steps; the cache layout (batch-major,
position-indexed) is chosen so that insertion is a dynamic_update_slice
per row (documented seam, not exercised here).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.common import ModelConfig, NO_SHARD, Sharder


def prefill_step(params, batch, cfg: ModelConfig,
                 sharder: Sharder = NO_SHARD):
    """Full-sequence forward; returns (last_logits, caches)."""
    logits, _, caches = T.forward(params, batch, cfg, sharder,
                                  mode="prefill", last_only=True)
    return logits[:, -1], caches


def serve_step(params, caches, tokens, pos, cfg: ModelConfig,
               sharder: Sharder = NO_SHARD, extra=None):
    """One decode step. tokens: [B, 1] (audio: [B, 1, n_codebooks]);
    pos: scalar int32 absolute position. Returns (logits, new_caches)."""
    batch = {"tokens": tokens}
    if extra:
        batch.update(extra)
    logits, _, caches = T.forward(params, batch, cfg, sharder,
                                  mode="decode", caches=caches, pos=pos)
    return logits[:, 0], caches


def generate(params, prompt_batch, cfg: ModelConfig, *, n_tokens: int,
             sharder: Sharder = NO_SHARD, temperature: float = 0.0,
             rng=None, max_len: int | None = None):
    """Greedy / sampled generation (host loop, jitted step)."""
    S = prompt_batch["tokens"].shape[1]
    max_len = max_len or (S + n_tokens)
    last, caches = prefill_step(params, prompt_batch, cfg, sharder)
    caches = T.pad_caches(caches, max_len)

    step = jax.jit(functools.partial(serve_step, cfg=cfg, sharder=sharder))

    outs = []
    tok = _pick(last, cfg, temperature, rng, 0)
    outs.append(tok)
    for i in range(1, n_tokens):
        logits, caches = step(params, caches, tok, jnp.int32(S + i - 1))
        tok = _pick(logits, cfg, temperature, rng, i)
        outs.append(tok)
    return jnp.concatenate(outs, axis=1)


def _pick(logits, cfg: ModelConfig, temperature, rng, i):
    """logits: [B, V] (audio: [B, n_cb, V]) -> next token [B, 1, ...]."""
    if temperature > 0:
        assert rng is not None
        k = jax.random.fold_in(rng, i)
        tok = jax.random.categorical(k, logits / temperature, axis=-1)
    else:
        tok = jnp.argmax(logits, axis=-1)
    if cfg.family == "audio":
        return tok[:, None, :]          # [B, 1, n_cb]
    return tok[:, None]
