"""musicgen-large [audio] — decoder-only over EnCodec tokens: 48L
d_model=2048 32H (MHA kv=32) d_ff=8192 vocab=2048, 4 codebooks
[arXiv:2306.05284]. Text-conditioning cross-attention is out of scope
(stub: unconditional decoder; see docs/ARCHITECTURE.md, "Model and
training integrations")."""
from repro.models.common import ModelConfig

ARCH = "musicgen-large"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="audio", n_layers=48, d_model=2048, d_ff=8192,
        vocab=2048, n_heads=32, n_kv=32, head_dim=64, mlp="gelu",
        n_codebooks=4, param_dtype="bf16", activ_dtype="bf16")


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="audio", n_layers=2, d_model=64,
        d_ff=128, vocab=64, n_heads=4, n_kv=4, head_dim=16, mlp="gelu",
        n_codebooks=4, max_seq=64)
