"""Architecture registry: --arch <id> resolves here.

Each module defines full() (the exact published config) and smoke()
(a reduced same-family config for CPU tests). SHAPES lists the assigned
input-shape cells; SKIP_CELLS marks (arch, shape) pairs excluded per the
assignment (long_500k needs sub-quadratic attention — only the SSM /
hybrid archs run it; see docs/ARCHITECTURE.md, "Model and training integrations").
"""
from __future__ import annotations

import dataclasses
import importlib

_MODULES = {
    "pixtral-12b": "pixtral_12b",
    "nemotron-4-15b": "nemotron_15b",
    "gemma-2b": "gemma_2b",
    "nemotron-4-340b": "nemotron_340b",
    "granite-34b": "granite_34b",
    "rwkv6-3b": "rwkv6_3b",
    "musicgen-large": "musicgen_large",
    "zamba2-2.7b": "zamba2_2p7b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite",
    "deepseek-v3-671b": "deepseek_v3_671b",
}

ARCHS = tuple(_MODULES)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str           # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# long_500k runs only for O(1)-state decoders (assignment rule).
LONG_OK = frozenset({"rwkv6-3b", "zamba2-2.7b"})


def cells():
    """All 40 (arch, shape) cells with a runnable flag."""
    out = []
    for a in ARCHS:
        for s in SHAPES:
            runnable = s != "long_500k" or a in LONG_OK
            out.append((a, s, runnable))
    return out


def get_config(arch: str, *, smoke: bool = False):
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.smoke() if smoke else mod.full()
