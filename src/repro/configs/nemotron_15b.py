"""nemotron-4-15b [dense] — 32L d_model=6144 48H (GQA kv=8) d_ff=24576
vocab=256000, squared-ReLU MLP [arXiv:2402.16819]."""
from repro.models.common import ModelConfig

ARCH = "nemotron-4-15b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense", n_layers=32, d_model=6144, d_ff=24576,
        vocab=256000, n_heads=48, n_kv=8, head_dim=128, mlp="relu2",
        rope_theta=1e6, param_dtype="bf16", activ_dtype="bf16")


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="dense", n_layers=2, d_model=96,
        d_ff=192, vocab=256, n_heads=6, n_kv=2, head_dim=16, mlp="relu2",
        max_seq=64)
