"""nemotron-4-340b [dense] — 96L d_model=18432 96H (GQA kv=8, head_dim
192) d_ff=73728 vocab=256000, squared-ReLU [arXiv:2402.16819]."""
from repro.models.common import ModelConfig

ARCH = "nemotron-4-340b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense", n_layers=96, d_model=18432, d_ff=73728,
        vocab=256000, n_heads=96, n_kv=8, head_dim=192, mlp="relu2",
        rope_theta=1e6, param_dtype="bf16", activ_dtype="bf16")


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="dense", n_layers=3, d_model=96,
        d_ff=384, vocab=256, n_heads=6, n_kv=2, head_dim=16, mlp="relu2",
        max_seq=64)
