"""gemma-2b [dense] — 18L d_model=2048 8H (MQA kv=1, head_dim=256)
d_ff=16384, GeGLU, vocab=256000 [arXiv:2403.08295]."""
from repro.models.common import ModelConfig

ARCH = "gemma-2b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense", n_layers=18, d_model=2048, d_ff=16384,
        vocab=256000, n_heads=8, n_kv=1, head_dim=256, mlp="geglu",
        param_dtype="bf16", activ_dtype="bf16")


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="dense", n_layers=2, d_model=64,
        d_ff=256, vocab=256, n_heads=4, n_kv=1, head_dim=32, mlp="geglu",
        max_seq=64)
