"""zamba2-2.7b [hybrid] — 54 Mamba-2 blocks (d_model=2560, ssm_state=64)
with one param-shared attention+MLP block applied every 9 blocks
(32H kv=32, d_ff=10240) [arXiv:2411.15242]."""
from repro.models.common import ModelConfig

ARCH = "zamba2-2.7b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="hybrid", n_layers=54, d_model=2560, d_ff=10240,
        vocab=32000, n_heads=32, n_kv=32, head_dim=80, mlp="geglu",
        ssm_state=64, ssm_head_dim=64, attn_every=9,
        param_dtype="bf16", activ_dtype="bf16")


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="hybrid", n_layers=4, d_model=64,
        d_ff=128, vocab=256, n_heads=4, n_kv=4, head_dim=16, mlp="geglu",
        ssm_state=16, ssm_head_dim=16, attn_every=2, max_seq=64)
