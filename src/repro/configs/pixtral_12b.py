"""pixtral-12b [vlm] — Pixtral-ViT frontend (stubbed) + Mistral-Nemo
backbone. 40L d_model=5120 32H (GQA kv=8, head_dim=128) d_ff=14336
vocab=131072 [hf:mistralai/Pixtral-12B-2409]. The vision tower is a stub:
input_specs() feeds precomputed patch embeddings for the first
n_img_tokens positions."""
from repro.models.common import ModelConfig

ARCH = "pixtral-12b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="vlm", n_layers=40, d_model=5120, d_ff=14336,
        vocab=131072, n_heads=32, n_kv=8, head_dim=128, mlp="swiglu",
        n_img_tokens=256, rope_theta=1e6,
        param_dtype="bf16", activ_dtype="bf16")


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="vlm", n_layers=2, d_model=64,
        d_ff=128, vocab=256, n_heads=4, n_kv=2, head_dim=16, mlp="swiglu",
        n_img_tokens=8, max_seq=64)
