"""deepseek-v2-lite-16b [moe] — 27L d_model=2048, MLA (kv_lora=512,
nope=128, rope=64, v=128, 16H), MoE 64 routed top-6 + 2 shared experts,
expert d_ff=1408, first layer dense (d_ff=10944), vocab=102400
[arXiv:2405.04434]."""
from repro.models.common import ModelConfig

ARCH = "deepseek-v2-lite-16b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="moe", n_layers=27, d_model=2048, d_ff=10944,
        vocab=102400, n_heads=16, n_kv=16, mla=True, kv_lora=512, q_lora=0,
        rope_head_dim=64, nope_head_dim=128, v_head_dim=128,
        moe_experts=64, moe_topk=6, moe_shared=2, moe_dff=1408,
        moe_first_dense=1, param_dtype="bf16", activ_dtype="bf16")


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="moe", n_layers=3, d_model=64,
        d_ff=192, vocab=256, n_heads=4, n_kv=4, mla=True, kv_lora=32,
        q_lora=0, rope_head_dim=16, nope_head_dim=32, v_head_dim=32,
        moe_experts=8, moe_topk=2, moe_shared=2, moe_dff=96,
        moe_first_dense=1, moe_capacity_factor=8.0, max_seq=64)
