"""granite-34b [dense/code] — 88L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152, gpt-bigcode lineage => plain GELU 4x MLP [arXiv:2405.04324]."""
from repro.models.common import ModelConfig

ARCH = "granite-34b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense", n_layers=88, d_model=6144, d_ff=24576,
        vocab=49152, n_heads=48, n_kv=1, head_dim=128, mlp="gelu",
        param_dtype="bf16", activ_dtype="bf16")


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="dense", n_layers=2, d_model=64,
        d_ff=256, vocab=256, n_heads=4, n_kv=1, head_dim=16, mlp="gelu",
        max_seq=64)
