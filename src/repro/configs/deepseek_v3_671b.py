"""deepseek-v3-671b [moe] — 61L d_model=7168, MLA (q_lora=1536,
kv_lora=512, nope=128, rope=64, v=128, 128H), MoE 256 routed top-8 +
1 shared expert, expert d_ff=2048, first 3 layers dense (d_ff=18432),
vocab=129280 [arXiv:2412.19437]. MTP head is out of scope (architecture stub; docs/ARCHITECTURE.md)."""
from repro.models.common import ModelConfig

ARCH = "deepseek-v3-671b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="moe", n_layers=61, d_model=7168, d_ff=18432,
        vocab=129280, n_heads=128, n_kv=128, mla=True, kv_lora=512,
        q_lora=1536, rope_head_dim=64, nope_head_dim=128, v_head_dim=128,
        moe_experts=256, moe_topk=8, moe_shared=1, moe_dff=2048,
        moe_first_dense=3, param_dtype="bf16", activ_dtype="bf16")


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="moe", n_layers=4, d_model=64,
        d_ff=192, vocab=256, n_heads=4, n_kv=4, mla=True, kv_lora=32,
        q_lora=48, rope_head_dim=16, nope_head_dim=32, v_head_dim=32,
        moe_experts=8, moe_topk=2, moe_shared=1, moe_dff=96,
        moe_first_dense=2, moe_capacity_factor=8.0, max_seq=64)
