"""rwkv6-3b [ssm] — Finch: 32L d_model=2560, attention-free data-dependent
decay, d_ff=8960 vocab=65536, head dim 64 (40 heads) [arXiv:2404.05892]."""
from repro.models.common import ModelConfig

ARCH = "rwkv6-3b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="rwkv", n_layers=32, d_model=2560, d_ff=8960,
        vocab=65536, ssm_head_dim=64,
        param_dtype="bf16", activ_dtype="bf16")


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="rwkv", n_layers=2, d_model=64,
        d_ff=128, vocab=256, ssm_head_dim=16, max_seq=64)
