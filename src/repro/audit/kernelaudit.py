"""Static Pallas kernel checks: accumulators, VMEM budget, index bounds.

The jaxpr/HLO audits see the *CPU reference* lowering; the Pallas kernels
in :mod:`repro.kernels` are what actually runs on an MXU backend, and
three of their invariants are checkable without any accelerator:

* **kernel-accumulator-dtype** — every VMEM scratch accumulator must be
  f32. A bf16 accumulator silently halves the mantissa of every partial
  sum and no numeric test at leaf-sized n will catch it (the error is
  O(sqrt(k)) ulps), so this is a static rule, not a tolerance.
* **kernel-vmem-budget** — the per-grid-step working set (double-buffered
  in/out blocks + scratch) must fit the ~16 MiB/core VMEM an MXU offers;
  an oversize block spec fails at Mosaic compile time on hardware but
  passes silently in interpret mode and on CPU.
* **kernel-index-bounds** — every ``BlockSpec`` index map, evaluated at
  every grid point of the paper geometries, must return block indices
  inside the (padded) operand. The triangular-packed maps
  (``_tri_decode``) are exactly the kind of closed-form index arithmetic
  that goes out of bounds one tile past a boundary.

Capture works by patching ``jax.experimental.pallas.pallas_call`` with a
recording wrapper and tracing each kernel entry under ``jax.eval_shape``
at ``PAPER_CONFIGS`` geometries (leaf = 256): nothing executes, but every
``pallas_call`` records its grid, specs, scratch shapes and operand
avals. Index maps are then evaluated eagerly with concrete ints.
"""
from __future__ import annotations

import contextlib
import itertools
from dataclasses import dataclass

from repro.audit.report import CheckResult, Violation

#: default per-grid-step VMEM budget — one TPU core's worth (see
#: /opt/skills/guides/pallas_guide.md: ~16 MB VMEM per core).
VMEM_BUDGET_BYTES = 16 * 1024 * 1024

#: grid-step footprint model: streamed in/out blocks are double-buffered
#: by the Pallas pipeline, scratch is single-copy.
_STREAM_COPIES = 2


@dataclass
class KernelCall:
    """One recorded ``pallas_call`` with everything the checks need."""
    name: str
    grid: tuple
    in_specs: tuple
    out_specs: tuple
    scratch: tuple
    operands: tuple          # ((shape, np-dtype-name), ...) per in_spec
    out_shapes: tuple        # ((shape, np-dtype-name), ...) per out_spec
    entry: str = ""


def _kernel_name(fn) -> str:
    while hasattr(fn, "func"):          # unwrap functools.partial
        fn = fn.func
    return getattr(fn, "__name__", repr(fn))


def _as_tuple(x):
    if x is None:
        return ()
    return tuple(x) if isinstance(x, (list, tuple)) else (x,)


@contextlib.contextmanager
def _capture(into: list):
    """Patch ``pallas_call`` so every traced call appends a KernelCall."""
    import numpy as np
    import jax.experimental.pallas as plmod
    real = plmod.pallas_call

    def recording(kernel, *args, **kw):
        inner = real(kernel, *args, **kw)

        def wrapped(*ops):
            outs = _as_tuple(kw.get("out_shape"))
            into.append(KernelCall(
                name=_kernel_name(kernel),
                grid=_as_tuple(kw.get("grid")),
                in_specs=_as_tuple(kw.get("in_specs")),
                out_specs=_as_tuple(kw.get("out_specs")),
                scratch=_as_tuple(kw.get("scratch_shapes")),
                operands=tuple((tuple(o.shape), np.dtype(o.dtype).name)
                               for o in ops),
                out_shapes=tuple((tuple(o.shape), np.dtype(o.dtype).name)
                                 for o in outs)))
            return inner(*ops)
        return wrapped

    plmod.pallas_call = recording
    try:
        yield
    finally:
        plmod.pallas_call = real


def _paper_entries(leaf: int):
    """Yield ``(entry_label, thunk)`` pairs; each thunk eval_shapes one
    kernel entry at a paper geometry (leaf-multiple panels, 256 leaf)."""
    import jax
    import jax.numpy as jnp
    from repro.core.plan import build_plan
    from repro.core.precision import PAPER_CONFIGS
    from repro.kernels import panel as kpanel
    from repro.kernels import potrf as kpotrf
    from repro.kernels import qgemm as kqgemm
    from repro.kernels import residual as kresidual
    from repro.kernels import syrk as ksyrk
    from repro.kernels import trsm as ktrsm

    b = leaf
    m, n = 3 * b, 4 * b
    S = jax.ShapeDtypeStruct

    yield "qgemm[f16]", lambda: jax.eval_shape(
        lambda a, bb: kqgemm.qgemm(a, bb, 1.0),
        S((m, b), jnp.float16), S((b, b), jnp.float16))
    yield "qgemm[int8,c,trans_b]", lambda: jax.eval_shape(
        lambda a, bb, c: kqgemm.qgemm(a, bb, 1.0, c=c, beta=1.0,
                                      trans_b=True),
        S((m, b), jnp.int8), S((b, b), jnp.int8), S((m, b), jnp.float32))
    yield "trsm_leaf", lambda: jax.eval_shape(
        lambda bb, linv: ktrsm.trsm_leaf(bb, linv=linv),
        S((m, b), jnp.float32), S((b, b), jnp.float32))
    yield "potrf_leaf", lambda: jax.eval_shape(
        kpotrf.potrf_leaf, S((b, b), jnp.float32))
    yield "tri_inv_leaf", lambda: jax.eval_shape(
        kpotrf.tri_inv_leaf, S((b, b), jnp.float32))
    yield "syrk_leaf", lambda: jax.eval_shape(
        lambda c, a: ksyrk.syrk_leaf(c, a, 1.0, 1.0),
        S((b, b), jnp.float32), S((b, n), jnp.float16))
    yield "syrk_packed", lambda: jax.eval_shape(
        lambda c, a: ksyrk.syrk_packed(c, a, 1.0, 1.0),
        S((n, n), jnp.float32), S((n, 2 * b), jnp.float16))
    yield "residual_fused", lambda: jax.eval_shape(
        kresidual.residual_fused,
        S((n, n), jnp.float32), S((n, 8), jnp.float32),
        S((n, 8), jnp.float32))

    meta = build_plan(n, PAPER_CONFIGS["f16x3_f32"]).panel_meta(0)
    yield "panel_update", lambda: jax.eval_shape(
        lambda linv, a21, c: kpanel.panel_update(
            linv, a21, c, store_names=meta.store_names,
            store_quants=meta.store_quants, pair_names=meta.pair_names,
            pair_quants=meta.pair_quants, rounding=True),
        S((b, b), jnp.float32), S((m, b), jnp.float16),
        S((m, m), jnp.float32))


def capture_paper_kernels(leaf: int = 256) -> list:
    """Trace every kernel entry at paper geometries; return KernelCalls.

    ``tri_inv_leaf`` is traced both standalone and inside ``trsm_leaf``;
    duplicate (entry, kernel) records are harmless — each is checked
    against its own captured geometry.
    """
    import jax
    # the entries are jit-wrapped: a cached trace would skip the patched
    # pallas_call entirely and the audit would silently see nothing
    jax.clear_caches()
    calls: list[KernelCall] = []
    with _capture(calls):
        mark = 0
        for label, thunk in _paper_entries(leaf):
            thunk()
            for c in calls[mark:]:
                c.entry = label
            mark = len(calls)
    return calls


def _block_bytes(spec, shape, dtype_name) -> int:
    from repro.core.dtypes import BYTES
    from repro.core.dtypes import NP_TO_HLO
    bs = spec.block_shape if spec.block_shape is not None else shape
    elems = 1
    for d in bs:
        elems *= int(d)
    return elems * BYTES[NP_TO_HLO[dtype_name]]


def _index_violations(call: KernelCall, target: str) -> list:
    """Evaluate every index map at every grid point; flag OOB blocks."""
    import jax.numpy as jnp
    viols = []
    points = (list(itertools.product(*(range(g) for g in call.grid)))
              if call.grid else [()])
    specs = ([("in", i, s, call.operands[i])
              for i, s in enumerate(call.in_specs)]
             + [("out", i, s, call.out_shapes[i])
                for i, s in enumerate(call.out_specs)])
    for side, i, spec, (shape, _) in specs:
        bs = spec.block_shape if spec.block_shape is not None else shape
        nblocks = [-(-int(d) // int(t)) for d, t in zip(shape, bs)]
        for pt in points:
            # index maps may do jnp arithmetic (_tri_decode) — feed them
            # concrete jnp scalars, evaluated eagerly
            idx = spec.index_map(*(jnp.int32(v) for v in pt))
            idx = tuple(int(v) for v in _as_tuple(idx))
            if len(idx) != len(nblocks):
                viols.append(Violation(
                    "kernel-index-bounds", target,
                    f"{call.entry}/{call.name}: {side}_spec[{i}] index map "
                    f"returned rank-{len(idx)} block index for rank-"
                    f"{len(nblocks)} operand at grid point {pt}"))
                break
            if any(v < 0 or v >= nb for v, nb in zip(idx, nblocks)):
                viols.append(Violation(
                    "kernel-index-bounds", target,
                    f"{call.entry}/{call.name}: {side}_spec[{i}] maps grid "
                    f"point {pt} to block {idx}, outside the "
                    f"{tuple(nblocks)}-block operand of shape {shape}"))
                break
    return viols


def audit_kernels(leaf: int = 256, *,
                  vmem_budget: int = VMEM_BUDGET_BYTES) -> CheckResult:
    """Run all three static checks over every captured kernel call."""
    import numpy as np
    target = f"kernels[leaf={leaf}]"
    try:
        calls = capture_paper_kernels(leaf)
    except Exception as exc:  # pallas unavailable -> report, don't crash
        return CheckResult("kernels", target, [Violation(
            "kernel-untestable", target,
            f"could not trace Pallas kernels: {exc!r}", severity="warn")])
    if not calls:
        return CheckResult("kernels", target, [Violation(
            "kernel-untestable", target,
            "no pallas_call captured — the recording patch missed every "
            "kernel entry (trace cache? import path?)")])
    viols = []
    for call in calls:
        where = f"{call.entry}/{call.name}"
        for j, sc in enumerate(call.scratch):
            dt = np.dtype(sc.dtype)
            if dt.kind == "f" and dt.itemsize != 4:
                viols.append(Violation(
                    "kernel-accumulator-dtype", target,
                    f"{where}: scratch[{j}] is a {dt.name} accumulator "
                    f"({tuple(sc.shape)}); partial sums must accumulate "
                    "in f32"))
        step = 0
        for spec, (shape, dtn) in zip(call.in_specs, call.operands):
            step += _STREAM_COPIES * _block_bytes(spec, shape, dtn)
        for spec, (shape, dtn) in zip(call.out_specs, call.out_shapes):
            step += _STREAM_COPIES * _block_bytes(spec, shape, dtn)
        for sc in call.scratch:
            elems = 1
            for d in sc.shape:
                elems *= int(d)
            step += elems * np.dtype(sc.dtype).itemsize
        if step > vmem_budget:
            viols.append(Violation(
                "kernel-vmem-budget", target,
                f"{where}: per-grid-step working set {step} B "
                f"(double-buffered blocks + scratch) exceeds the "
                f"{vmem_budget} B VMEM budget"))
        viols.extend(_index_violations(call, target))
    return CheckResult("kernels", target, viols)
