"""Compiled-HLO cross-checks: what XLA actually emits vs the plan.

The jaxpr-level checks (:mod:`repro.audit.conformance`) verify the
*traced* program; XLA can still break conformance after the fact —
constant-folding a GEMM away, fusing a convert out of existence, or
commuting a 16-bit collective convert ahead of the gather (doubling the
wire bytes; the exact bug ``_gather_panel``'s u16 bitcast exists to
prevent). These checks parse ``compiled.as_text()`` through the extended
:func:`repro.launch.hloparse.census` and reconcile:

* total dot FLOPs against ``PrecisionPlan.expected_dot_flops`` (exact:
  the blocked schedule's GEMMs all survive as HLO dots on every backend
  we compile for),
* per-wire-dtype collective bytes against ``ShardedPlan.comm_table()``
  (exact: P-1 panel gathers + P diagonal all-reduces + one (P,) f32
  scale gather per quantized panel),
* per-operand-dtype dot FLOPs, reported as a *warning* on CPU — XLA CPU
  legally promotes f16/bf16 dots into f32 containers (the value-level
  rounding still applies), so narrow dot dtypes only appear on MXU
  backends where the check tightens to an error.
"""
from __future__ import annotations

import numpy as np

from repro.audit.report import CheckResult, Violation
from repro.core.dtypes import BYTES, WIRE_DTYPE
from repro.core.plan import ShardedPlan, build_plan
from repro.core.precision import PrecisionConfig

#: relative slack on exact-FLOP reconciliation (float accumulation only)
_REL_TOL = 1e-9


def _compile_hlo(fn, *structs) -> str:
    import jax
    return jax.jit(fn).lower(*structs).compile().as_text()


def audit_hlo_single(n: int, cfg: PrecisionConfig) -> CheckResult:
    """Compiled blocked_potrf: dot-FLOP reconciliation vs the plan."""
    import jax
    import jax.numpy as jnp
    from repro.core.blocked import blocked_potrf
    from repro.launch.hloparse import census
    target = f"hlo-blocked[n={n},{cfg.describe()}]"
    hlo = _compile_hlo(lambda x: blocked_potrf(x, cfg),
                       jax.ShapeDtypeStruct((n, n), jnp.float32))
    cen = census(hlo)
    plan = build_plan(n, cfg)
    want_by = plan.expected_dot_flops(cfg.high_name)
    want = sum(want_by.values())
    viols = []
    got = cen["flops"]
    if want and abs(got - want) > _REL_TOL * want:
        viols.append(Violation(
            "hlo-dot-flops", target,
            f"compiled module runs {got:.0f} dot flops, plan prices "
            f"{want:.0f} — XLA folded or duplicated a planned GEMM"))
    by = cen["dot_flops_by_dtype"]
    narrow_planned = {k: v for k, v in want_by.items()
                      if k not in ("f32", "f64")}
    narrow_keys = [k for k in by if not k.startswith(("f32", "f64"))]
    if narrow_planned and not narrow_keys:
        viols.append(Violation(
            "hlo-dot-dtype", target,
            f"plan prices {sum(narrow_planned.values()):.0f} flops at "
            f"{sorted(narrow_planned)} but every compiled dot is wide "
            f"({sorted(by)}); expected on CPU (XLA promotes narrow dots "
            "into f32 containers; value rounding still applies) — on an "
            "MXU backend this is a lost speedup", severity="warn"))
    return CheckResult("hlo-blocked", target, viols)


def audit_hlo_dist(n: int, cfg: PrecisionConfig, nshards: int, *,
                   compress: bool = True, sharded=None) -> CheckResult:
    """Compiled dist_cholesky: exact per-wire-dtype collective bytes."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core.distributed import dist_cholesky
    from repro.launch.hloparse import census
    target = (f"hlo-dist[n={n},P={nshards},{cfg.describe()}"
              f"{'' if compress else ',raw-wire'}]")
    devs = jax.devices()
    if len(devs) < nshards:
        return CheckResult("hlo-dist", target, [Violation(
            "dist-untestable", target,
            f"only {len(devs)} devices visible, need {nshards}",
            severity="warn")])
    mesh = Mesh(np.array(devs[:nshards]), ("model",))
    hlo = _compile_hlo(
        lambda x: dist_cholesky(x, mesh, cfg, compress_comm=compress),
        jax.ShapeDtypeStruct((n, n), jnp.float32))
    del jnp
    cen = census(hlo)
    sp = sharded or ShardedPlan(build_plan(n, cfg), nshards)
    w = n // nshards

    exp: dict[str, float] = {}
    # P diagonal broadcasts: psum of the masked (w, w) block -> f32
    # all-reduce per panel
    exp["f32"] = float(nshards * w * w * 4)
    n_scale = 0
    for row in sp.comm_table()[:nshards - 1]:
        wire = row["wire"] if compress else "f32"
        exp[wire] = exp.get(wire, 0.0) + float(nshards * w * w * BYTES[wire])
        if compress and row["quant"]:
            exp["f32"] += nshards * 4           # (P,) f32 scale gather
            n_scale += 1

    got = cen["collective_bytes_by_dtype"]
    viols = []
    for dt in sorted(set(exp) | set(got)):
        g, e = got.get(dt, 0.0), exp.get(dt, 0.0)
        if g == e:
            continue
        panels = [row["panel"] for row in sp.comm_table()[:nshards - 1]
                  if (row["wire"] if compress else "f32") == dt]
        viols.append(Violation(
            "hlo-collective-bytes", target,
            f"{dt} collective bytes: compiled {g:.0f}, plan prices "
            f"{e:.0f} (panels gathered on a {dt} wire: {panels}) — a "
            "convert commuted across the collective or a gather changed "
            "wire dtype"))
    counts = cen["collectives"]
    want_ag = (nshards - 1) + (n_scale if compress else 0)
    if counts["all-gather"]["count"] != want_ag:
        viols.append(Violation(
            "hlo-collective-bytes", target,
            f"compiled all-gather count {counts['all-gather']['count']:.0f}"
            f" != scheduled {want_ag}"))
    if counts["all-reduce"]["count"] != nshards:
        viols.append(Violation(
            "hlo-collective-bytes", target,
            f"compiled all-reduce count {counts['all-reduce']['count']:.0f}"
            f" != scheduled {nshards} diagonal broadcasts"))
    return CheckResult("hlo-dist", target, viols)
