"""Plan-conformance checks over traced jaxprs (nothing is executed).

Each ``audit_*`` function traces one solver entry point with
``jax.make_jaxpr`` on ShapeDtypeStructs, runs the
:mod:`repro.audit.dtypeflow` walker, and reconciles the result against
the static expectations :class:`repro.core.plan.PrecisionPlan` exposes:

* ``audit_blocked`` — every dot's effective precision and every
  storage-round/quantize event in ``blocked_potrf`` matches the plan's
  per-tile compute/storage levels (FLOPs and rounded elements are
  compared *exactly*, per dtype); the executed plan's tables and
  ``panel_meta`` agree with the pristine ``build_plan`` geometry (this
  is what names the exact tile when a mutated plan sneaks in); no
  f16<->bf16 double-round and no promotion wider than the container.
* ``audit_solve`` / ``audit_refine`` — the triangular solves and the
  refinement loop are lossless: all dots wide, zero rounding events.
* ``audit_dist`` — the distributed panel sweep's collectives: exactly
  ``P-1`` panel gathers whose wire dtype matches
  ``ShardedPlan.comm_name(j)`` panel by panel, ``P`` diagonal psums, and
  scale gathers exactly where the plan quantizes the wire.
"""
from __future__ import annotations

import numpy as np

from repro.audit import dtypeflow
from repro.audit.report import CheckResult, Violation
from repro.core.dtypes import NP_TO_HLO, WIRE_DTYPE
from repro.core.plan import PrecisionPlan, ShardedPlan, build_plan
from repro.core.precision import PrecisionConfig


def _structs(*shapes, dtype=None):
    import jax
    import jax.numpy as jnp
    dt = dtype or jnp.float32
    return tuple(jax.ShapeDtypeStruct(s, dt) for s in shapes)


def _diff_tables(plan_exec, pristine, target: str) -> list:
    """Exact-tile diff of an executed plan against the pristine geometry."""
    out = []
    for attr, what in (("levels", "compute"), ("store_levels", "storage")):
        a = np.asarray(getattr(plan_exec, attr))
        b = np.asarray(getattr(pristine, attr))
        if a.shape != b.shape:
            out.append(Violation(
                "plan-table-mismatch", target,
                f"{what}-level table shape {a.shape} != plan {b.shape}"))
            continue
        for i, j in zip(*np.nonzero(a != b)):
            if j > i:
                continue            # mirrored upper triangle
            out.append(Violation(
                "plan-table-mismatch", target,
                f"{what} level of tile ({i}, {j}) is {int(a[i, j])} "
                f"({plan_exec.cfg.name_at(int(a[i, j]))}), plan says "
                f"{int(b[i, j])} ({pristine.cfg.name_at(int(b[i, j]))})",
                tile=(int(i), int(j))))
    return out


def _diff_meta(plan_exec, target: str) -> list:
    """Cross-check the executed plan's ``panel_meta`` (what the blocked
    schedule actually consumes) against its own level tables — catches a
    schedule that drops or rewrites a storage round without touching the
    tables."""
    out = []
    for p in range(plan_exec.ntiles - 1):
        got = plan_exec.panel_meta(p)
        want = PrecisionPlan.panel_meta(plan_exec, p)
        if got == want:
            continue
        for k, (g, w) in enumerate(zip(got.store_names, want.store_names)):
            if g != w:
                out.append(Violation(
                    "plan-meta-mismatch", target,
                    f"panel {p}: storage round of tile ({p + 1 + k}, {p}) "
                    f"is {g!r} in the executed schedule, plan tables say "
                    f"{w!r}", panel=p, tile=(p + 1 + k, p)))
        for i, (gr, wr) in enumerate(zip(got.pair_names, want.pair_names)):
            for j, (g, w) in enumerate(zip(gr, wr)):
                if g != w:
                    out.append(Violation(
                        "plan-meta-mismatch", target,
                        f"panel {p}: trailing pair ({p + 1 + i}, "
                        f"{p + 1 + j}) computes at {g!r}, plan tables say "
                        f"{w!r}", panel=p, tile=(p + 1 + i, p + 1 + j)))
        if not out:
            out.append(Violation(
                "plan-meta-mismatch", target,
                f"panel {p}: quant flags differ from plan tables",
                panel=p))
    return out


def _attribute_panels(plan_exec, pristine, container, kind) -> str:
    """Name the panels whose expectations differ (trace-level findings
    can only localize to the panel granularity)."""
    bad = []
    for p in range(pristine.ntiles - 1):
        if kind == "dots":
            a = plan_exec.panel_dot_flops(p, container)
            b = pristine.panel_dot_flops(p, container)
        else:
            a = plan_exec.panel_round_elems(p, container)
            b = pristine.panel_round_elems(p, container)
        if a != b:
            bad.append(p)
    return f" (panels {bad})" if bad else ""


def _flow_violations(res, pristine, plan_exec, container, target) -> list:
    out = []
    got_dots = res.dot_flops_by_name()
    want_dots = pristine.expected_dot_flops(container)
    if got_dots != want_dots:
        where = _attribute_panels(plan_exec, pristine, container, "dots")
        for nm in sorted(set(got_dots) | set(want_dots)):
            g, w = got_dots.get(nm, 0.0), want_dots.get(nm, 0.0)
            if g != w:
                out.append(Violation(
                    "plan-dot-precision", target,
                    f"{nm} GEMM flops traced={g:.0f} planned={w:.0f}"
                    + where))
    got_r = res.round_elems_by_name()
    want_r = pristine.expected_round_elems(container)
    if got_r != want_r:
        where = _attribute_panels(plan_exec, pristine, container, "rounds")
        for nm in sorted(set(got_r) | set(want_r)):
            g, w = got_r.get(nm, 0), want_r.get(nm, 0)
            if g < w:
                out.append(Violation(
                    "plan-missing-round", target,
                    f"{nm} storage-round events cover {g} elements, plan "
                    f"requires {w}" + where))
            elif g > w:
                out.append(Violation(
                    "plan-extra-round", target,
                    f"{nm} storage-round events cover {g} elements, plan "
                    f"allows only {w}" + where))
    for r in res.double_rounds():
        out.append(Violation(
            "double-rounding", target,
            f"value on the {r.prev} grid re-rounded to {r.name} "
            f"({r.elems} elements): incommensurate 16-bit grids"))
    from repro.core.dtypes import BYTES
    cw = BYTES[container]
    for src, dst, elems in res.promotions:
        if BYTES.get(dst, 0) > cw:
            out.append(Violation(
                "promotion", target,
                f"unplanned {src}->{dst} promotion of {elems} elements "
                f"(container is {container})"))
    return out


def audit_blocked(n: int, cfg: PrecisionConfig, *, plan=None,
                  label: str | None = None) -> CheckResult:
    """Dtype-flow conformance of ``blocked_potrf`` at size ``n``.

    ``plan`` overrides the executed plan (the mutation self-test's
    injection point); expectations always come from the pristine
    ``build_plan(n, cfg)``.
    """
    from repro.core.blocked import blocked_potrf
    target = label or f"blocked[n={n},{cfg.describe()}]"
    pristine = build_plan(n, cfg)
    plan_exec = plan if plan is not None else pristine
    container = cfg.high_name
    viols = _diff_tables(plan_exec, pristine, target)
    viols += _diff_meta(plan_exec, target)
    (a,) = _structs((n, n))
    res = dtypeflow.trace(blocked_potrf, a, cfg=cfg, plan=plan_exec)
    viols += _flow_violations(res, pristine, plan_exec, container, target)
    return CheckResult("blocked-conformance", target, viols)


def audit_solve(n: int, cfg: PrecisionConfig, nrhs: int = 8) -> CheckResult:
    """The triangular solves must be lossless: O(n^2) work, so any
    narrow dot or rounding event there costs digits for nothing."""
    from repro.core.blocked import blocked_trsm_left
    target = f"trsm[n={n},{cfg.describe()}]"
    b, l = _structs((n, nrhs), (n, n))
    viols = []
    for trans in (False, True):
        res = dtypeflow.trace(
            lambda bb, ll: blocked_trsm_left(bb, ll, cfg, trans=trans),
            b, l)
        for nm, f in res.dot_flops_by_name().items():
            if nm != cfg.high_name:
                viols.append(Violation(
                    "solve-narrow", target,
                    f"trans={trans} solve runs {f:.0f} GEMM flops at "
                    f"{nm}; solves must stay at {cfg.high_name}"))
        rr = res.round_elems_by_name()
        if rr:
            viols.append(Violation(
                "solve-narrow", target,
                f"trans={trans} solve emits rounding events {rr}; the "
                "solve path must not round"))
    return CheckResult("solve-conformance", target, viols)


def audit_refine(n: int, cfg: PrecisionConfig, nrhs: int = 4,
                 sweeps: int = 2) -> CheckResult:
    """The refinement loop (given a factor) must be lossless outside the
    factor itself: residuals and corrections never round narrow."""
    import jax.numpy as jnp
    from repro.core.refine import RefineConfig, iterative_refine
    target = f"refine[n={n},{cfg.describe()}]"
    b = cfg.leaf
    a, rhs, l = _structs((n, n), (n, nrhs), (n, n))
    linvs = _structs((n // b, b, b))[0]
    rcfg = RefineConfig(max_sweeps=sweeps, tol=0.0)
    res = dtypeflow.trace(
        lambda aa, bb, ll, li: iterative_refine(
            aa, bb, cfg, rcfg, l=ll, linvs=li),
        a, rhs, l, linvs)
    del jnp
    viols = []
    for nm, f in res.dot_flops_by_name().items():
        if nm != cfg.high_name and nm != "f64":
            viols.append(Violation(
                "refine-narrow", target,
                f"refinement sweep runs {f:.0f} GEMM flops at {nm}; "
                f"sweeps must stay at >= {cfg.high_name}"))
    rr = res.round_elems_by_name()
    if rr:
        viols.append(Violation(
            "refine-narrow", target,
            f"refinement sweep emits rounding events {rr}"))
    return CheckResult("refine-conformance", target, viols)


def audit_dist(n: int, cfg: PrecisionConfig, nshards: int, *,
               compress: bool = True, sharded=None) -> CheckResult:
    """Traced-collective conformance of ``dist_cholesky``.

    ``sharded`` overrides the *expected* schedule source only when the
    self-test wants expectations from a pristine view while the traced
    executor runs a patched one; normally expectations come from
    ``ShardedPlan(build_plan(n, cfg), nshards)``.
    """
    import jax
    from jax.sharding import Mesh
    from repro.core.distributed import dist_cholesky
    target = (f"dist[n={n},P={nshards},{cfg.describe()}"
              f"{'' if compress else ',raw-wire'}]")
    devs = jax.devices()
    if len(devs) < nshards:
        return CheckResult("dist-conformance", target, [Violation(
            "dist-untestable", target,
            f"only {len(devs)} devices visible, need {nshards} "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count)",
            severity="warn")])
    mesh = Mesh(np.array(devs[:nshards]), ("model",))
    sp = sharded or ShardedPlan(build_plan(n, cfg), nshards)
    w = n // nshards
    (a,) = _structs((n, n))
    res = dtypeflow.trace(
        lambda x: dist_cholesky(x, mesh, cfg, compress_comm=compress), a)

    viols = []
    gathers = [c for c in res.collectives
               if c.prim == "all_gather" and c.shape == (w, w)]
    scale_gathers = [c for c in res.collectives
                     if c.prim == "all_gather" and c.shape == ()]
    psums = [c for c in res.collectives
             if c.prim == "psum" and c.shape == (w, w)]
    if len(gathers) != nshards - 1:
        viols.append(Violation(
            "collective-count", target,
            f"traced {len(gathers)} (w, w) panel gathers, schedule has "
            f"{nshards - 1}"))
    if len(psums) != nshards:
        viols.append(Violation(
            "collective-count", target,
            f"traced {len(psums)} diagonal psums, schedule has {nshards}"))
    expect_scales = 0
    for j, g in enumerate(gathers[:nshards - 1]):
        nm, q = sp.comm_name(j), sp.comm_quant(j)
        want_wire = WIRE_DTYPE[nm] if compress else "f32"
        got_wire = NP_TO_HLO.get(g.wire, g.wire)
        if got_wire != want_wire:
            viols.append(Violation(
                "collective-wire-dtype", target,
                f"panel {j} gathered on a {got_wire} wire; plan comm "
                f"level is {nm} => {want_wire} wire", panel=j))
        expect_scales += int(compress and q)
    if compress and len(scale_gathers) != expect_scales:
        viols.append(Violation(
            "collective-count", target,
            f"traced {len(scale_gathers)} scale gathers, quantized "
            f"schedule has {expect_scales}"))
    for c in res.collectives:
        if c.prim in ("psum", "all_gather") and "64" in c.wire:
            viols.append(Violation(
                "promotion", target,
                f"{c.prim} moves {c.wire} (shape {c.shape}); nothing in "
                "the distributed sweep is planned wider than f32"))
    return CheckResult("dist-conformance", target, viols)
