"""Precision-conformance auditor: does the executed solver match the plan?

Static verification in four layers, none of which runs the solver:

* :mod:`repro.audit.dtypeflow` — jaxpr dtype-flow analysis (which dots
  run at which effective precision, where values are rounded, what the
  collectives move),
* :mod:`repro.audit.conformance` — reconciles traced flows against
  ``PrecisionPlan`` / ``ShardedPlan`` expectations,
* :mod:`repro.audit.hloaudit` — re-checks the *compiled* HLO census,
* :mod:`repro.audit.kernelaudit` — static Pallas kernel invariants,
* :mod:`repro.audit.lint` — AST layering rules (stdlib-only),
* :mod:`repro.audit.selftest` — seeded mutations proving detection.

Run ``python -m repro.audit --smoke`` (CI) or ``--full``.

This ``__init__`` stays import-light on purpose: ``tools/perf_gate.py``
imports :mod:`repro.audit.report` from a jax-free venv.
"""
from repro.audit.report import (  # noqa: F401
    SCHEMA_VERSION, CheckResult, Violation, build_report, load_report,
    validate_report,
)
