"""Violation report schema for the precision-conformance auditor.

Stdlib-only on purpose: ``tools/perf_gate.py audit`` validates CI report
artifacts through :func:`validate_report` without importing jax, and
``repro.audit.lint`` emits :class:`Violation` rows from AST analysis
alone.  Severity is two-valued: ``error`` fails the audit (CLI exits
nonzero), ``warn`` is informational (e.g. per-dtype dot classification
on CPU, where XLA legally promotes narrow dots to f32 containers).

Report JSON layout (``python -m repro.audit --json out.json``)::

    {"schema": 1, "mode": "smoke",
     "checks": [{"name": "...", "target": "...", "violations": 0}, ...],
     "violations": [{"rule": "...", "target": "...", "message": "...",
                     "severity": "error", "panel": 1, "tile": [2, 1],
                     "path": null, "line": null}, ...],
     "summary": {"checks": N, "violations": N, "errors": N, "warns": N}}

docs/AUDIT.md explains how to read one and when ``# audit:
allow(<rule>)`` pragmas apply (lint rules only).
"""
from __future__ import annotations

import dataclasses
import json

SCHEMA_VERSION = 1

SEVERITIES = ("error", "warn")


@dataclasses.dataclass
class Violation:
    """One conformance failure, attributed as precisely as possible."""

    rule: str               # e.g. "plan-dot-precision", "kernel-vmem-budget"
    target: str             # what was audited: "blocked[n=1024,f16x3_f32]"
    message: str            # human-readable finding
    severity: str = "error"
    panel: int | None = None    # panel index, when attributable
    tile: tuple | None = None   # (i, j) leaf-tile index, when attributable
    path: str | None = None     # source file (lint rules)
    line: int | None = None     # source line (lint rules)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        if self.tile is not None:
            d["tile"] = list(self.tile)
        return d

    def __str__(self):
        where = ""
        if self.panel is not None:
            where += f" panel={self.panel}"
        if self.tile is not None:
            where += f" tile={tuple(self.tile)}"
        if self.path is not None:
            where += f" {self.path}:{self.line}"
        return (f"[{self.severity}] {self.rule} @ {self.target}{where}: "
                f"{self.message}")


@dataclasses.dataclass
class CheckResult:
    """One named check over one target, with its violations."""

    name: str
    target: str
    violations: list

    @property
    def ok(self) -> bool:
        return not any(v.severity == "error" for v in self.violations)


def build_report(mode: str, results: list) -> dict:
    """Assemble the schema'd JSON payload from CheckResults."""
    violations = [v for r in results for v in r.violations]
    return {
        "schema": SCHEMA_VERSION,
        "mode": mode,
        "checks": [{"name": r.name, "target": r.target,
                    "violations": len(r.violations)} for r in results],
        "violations": [v.to_dict() for v in violations],
        "summary": {
            "checks": len(results),
            "violations": len(violations),
            "errors": sum(v.severity == "error" for v in violations),
            "warns": sum(v.severity == "warn" for v in violations),
        },
    }


def validate_report(payload) -> list:
    """Structural validation of a report payload (list of error strings,
    empty = valid). This is what ``tools/perf_gate.py audit`` runs over
    the CI artifact."""
    errs = []
    if not isinstance(payload, dict):
        return [f"report is not an object: {type(payload).__name__}"]
    if payload.get("schema") != SCHEMA_VERSION:
        errs.append(f"schema != {SCHEMA_VERSION}: {payload.get('schema')!r}")
    if not isinstance(payload.get("mode"), str):
        errs.append(f"mode missing or not a string: {payload.get('mode')!r}")
    checks = payload.get("checks")
    if not isinstance(checks, list) or not checks:
        errs.append("checks empty or not a list")
        checks = []
    for i, c in enumerate(checks):
        if not isinstance(c, dict) or not {"name", "target",
                                           "violations"} <= set(c):
            errs.append(f"check {i} malformed: {c!r}")
    viols = payload.get("violations")
    if not isinstance(viols, list):
        errs.append("violations not a list")
        viols = []
    for i, v in enumerate(viols):
        if not isinstance(v, dict):
            errs.append(f"violation {i} not an object: {v!r}")
            continue
        for k in ("rule", "target", "message", "severity"):
            if not isinstance(v.get(k), str):
                errs.append(f"violation {i}: field {k!r} missing/not str")
        if v.get("severity") not in SEVERITIES:
            errs.append(f"violation {i}: bad severity {v.get('severity')!r}")
    summary = payload.get("summary")
    if not isinstance(summary, dict):
        errs.append("summary missing")
    else:
        for k in ("checks", "violations", "errors", "warns"):
            if not isinstance(summary.get(k), int):
                errs.append(f"summary.{k} missing/not int")
        if isinstance(viols, list) and isinstance(summary.get("violations"),
                                                  int) \
                and summary["violations"] != len(viols):
            errs.append(f"summary.violations={summary['violations']} != "
                        f"len(violations)={len(viols)}")
    return errs


def load_report(path: str) -> dict:
    with open(path) as f:
        return json.load(f)
