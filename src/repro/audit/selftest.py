"""Seeded-mutation self-test: prove the auditor *detects*, not just runs.

A conformance checker that always passes is indistinguishable from one
that checks nothing, so CI runs this before trusting a clean audit. Each
mutation plants exactly one precision bug in a seam the real executor
honors, re-runs the relevant audit with *pristine* expectations, and
demands a nonzero finding that names the mutated tile/panel:

1. **flip-compute-level** — one trailing pair tile's compute level is
   flipped in the executed plan's table. Both the static table diff and
   the traced dot-precision check must localize it.
2. **drop-storage-round** — one trailing row tile's storage rounding is
   deleted (its ``panel_meta`` claims a wide store), leaving the tables
   pristine: only the meta diff / traced missing-round check can see it.
3. **lossy-wire** — one panel's collective is swapped onto a lossy f16
   wire in an all-f32 ladder by patching the sharded-plan seam the
   distributed executor reads. The traced wire-dtype check must name the
   panel.

Mutations use n/P geometries the clean audits don't, so no trace cache
can leak a pristine jaxpr into a mutated run (the entry points are
un-jitted, this is belt and braces).
"""
from __future__ import annotations

import contextlib

from repro.audit.report import CheckResult, Violation

#: geometry reserved for mutations (distinct from smoke/full audits);
#: P=2 so the 6-tile plan splits evenly
_N, _P = 1536, 2
_CFG = "f16x3_f32"


def _expect(name: str, result: CheckResult, rules: tuple,
            needle: str) -> list:
    """The mutated audit must fail via one of ``rules`` AND localize the
    mutation (``needle`` appears in some violation)."""
    viols = []
    hits = [v for v in result.violations if v.rule in rules]
    if not hits:
        viols.append(Violation(
            "selftest-miss", name,
            f"seeded mutation went undetected: audit returned "
            f"{[v.rule for v in result.violations]}, expected one of "
            f"{list(rules)}"))
        return viols
    blob = " ".join(str(v) + f" panel={v.panel} tile={v.tile}"
                    for v in hits)
    if needle not in blob:
        viols.append(Violation(
            "selftest-miss", name,
            f"mutation detected but not localized: no violation names "
            f"{needle!r} (got: {blob[:300]})"))
    return viols


def _mut_flip_level():
    from repro.audit.conformance import audit_blocked
    from repro.core.plan import PrecisionPlan
    from repro.core.precision import PAPER_CONFIGS
    cfg = PAPER_CONFIGS[_CFG]
    mut = PrecisionPlan(_N, cfg)
    mut.levels = mut.levels.copy()
    i, j = mut.ntiles - 1, mut.ntiles - 2
    old = int(mut.levels[i, j])
    new = 0 if old != 0 else len(cfg.levels) - 1
    mut.levels[i, j] = mut.levels[j, i] = new
    res = audit_blocked(_N, cfg, plan=mut, label="selftest-mutant")
    return _expect(
        "flip-compute-level", res,
        ("plan-table-mismatch", "plan-dot-precision"), f"({i}, {j})")


def _mut_drop_round():
    from repro.audit.conformance import audit_blocked
    from repro.core.plan import PanelMeta, PrecisionPlan
    from repro.core.precision import PAPER_CONFIGS
    cfg = PAPER_CONFIGS[_CFG]
    base = PrecisionPlan(_N, cfg)
    ti, tp = base.ntiles - 1, 0         # last row tile of panel 0

    class _NoRound(PrecisionPlan):
        """Same tables, but one tile's storage round deleted from the
        meta the executor compiles in."""

        def __init__(self):
            self.__dict__.update(base.__dict__)

        def panel_meta(self, p):
            meta = PrecisionPlan.panel_meta(self, p)
            if p != tp:
                return meta
            k = ti - (p + 1)
            sn = list(meta.store_names)
            sq = list(meta.store_quants)
            sn[k], sq[k] = self.cfg.high_name, False
            return PanelMeta(tuple(sn), tuple(sq), meta.pair_names,
                             meta.pair_quants)

    res = audit_blocked(_N, cfg, plan=_NoRound(), label="selftest-mutant")
    return _expect(
        "drop-storage-round", res,
        ("plan-meta-mismatch", "plan-missing-round"), f"({ti}, {tp})")


def _mut_lossy_wire():
    import repro.core.distributed as dist
    from repro.audit.conformance import audit_dist
    from repro.core.plan import ShardedPlan, build_plan
    from repro.core.precision import PAPER_CONFIGS
    cfg = PAPER_CONFIGS["pure_f32"]     # every wire should be lossless

    class _Lossy:
        """ShardedPlan view whose panel-0 collective claims an f16 wire."""

        def __init__(self, sp):
            self._sp = sp

        def comm_name(self, j):
            return "f16" if j == 0 else self._sp.comm_name(j)

        def comm_quant(self, j):
            return False if j == 0 else self._sp.comm_quant(j)

        def __getattr__(self, k):
            return getattr(self._sp, k)

    @contextlib.contextmanager
    def patched():
        real = dist.shard
        dist.shard = lambda plan, ns: _Lossy(ShardedPlan(plan, ns))
        try:
            yield
        finally:
            dist.shard = real

    pristine = ShardedPlan(build_plan(_N, cfg), _P)
    with patched():
        res = audit_dist(_N, cfg, _P, sharded=pristine)
    if any(v.rule == "dist-untestable" for v in res.violations):
        return [Violation("selftest-skip", "lossy-wire",
                          "not enough devices to run the wire mutation",
                          severity="warn")]
    return _expect("lossy-wire", res, ("collective-wire-dtype",),
                   "panel 0")


def run_selftest() -> CheckResult:
    """Run all three mutations; ok iff every one was caught + localized."""
    viols = []
    for mut in (_mut_flip_level, _mut_drop_round, _mut_lossy_wire):
        viols.extend(mut())
    return CheckResult("selftest", "seeded-mutations", viols)
