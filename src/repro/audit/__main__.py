"""CLI driver: ``python -m repro.audit [--smoke|--full] [--json OUT]``.

``--smoke`` (the CI default) audits two representative ladders at one
size plus the kernel/lint/HLO checks — a couple of minutes on a laptop
CPU. ``--full`` sweeps every f32-high paper ladder, both solve/refine
consumers, the uncompressed-wire variant, and the mutation self-test.

Exit status is the audit verdict: 0 clean (warnings allowed), 1 any
error-severity violation, so CI can gate on the process code while the
JSON artifact carries the details.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

#: smoke ladders: one quantized-f16, one int8 (covers both round shapes)
_SMOKE_CFGS = ("f16x3_f32", "int8x3_f32")
#: every paper ladder with an f32 container (f64 containers route to the
#: jnp oracle and pure_f16 has no wide carrier to round from)
_FULL_CFGS = ("pure_f32", "f16_f32", "f16x3_f32", "f16x5_f32",
              "bf16_f32", "bf16x3_f32", "int8_f32", "int8x3_f32")
_N_JAXPR = 1024
_N_HLO = 512
_N_DIST, _P_DIST = 1024, 4


def _ensure_devices():
    """Give the dist audits a 4-way host mesh — must run before any jax
    import anywhere in the process."""
    if "jax" in sys.modules:             # too late to change the flag
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=4").strip()


def _run(mode: str, selftest: bool) -> list:
    from repro.audit import conformance, hloaudit
    from repro.audit.kernelaudit import audit_kernels
    from repro.audit.lint import lint_repo
    from repro.core.precision import PAPER_CONFIGS
    cfgs = _SMOKE_CFGS if mode == "smoke" else _FULL_CFGS
    results = [lint_repo(), audit_kernels()]
    for key in cfgs:
        cfg = PAPER_CONFIGS[key]
        results.append(conformance.audit_blocked(_N_JAXPR, cfg))
    rep = PAPER_CONFIGS[_SMOKE_CFGS[0]]
    results.append(conformance.audit_dist(_N_DIST, rep, _P_DIST))
    results.append(hloaudit.audit_hlo_single(_N_HLO, rep))
    results.append(hloaudit.audit_hlo_dist(_N_DIST, rep, _P_DIST))
    if mode == "full":
        results.append(conformance.audit_solve(_N_JAXPR, rep))
        results.append(conformance.audit_refine(_N_JAXPR, rep))
        for key in ("int8x3_f32", "bf16_f32"):
            results.append(conformance.audit_dist(
                _N_DIST, PAPER_CONFIGS[key], _P_DIST))
        results.append(conformance.audit_dist(
            _N_DIST, rep, _P_DIST, compress=False))
        results.append(hloaudit.audit_hlo_dist(
            _N_DIST, rep, _P_DIST, compress=False))
    if selftest or mode == "full":
        from repro.audit.selftest import run_selftest
        results.append(run_selftest())
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.audit",
        description="Static precision-conformance audit of the solver "
                    "against its PrecisionPlan.")
    g = ap.add_mutually_exclusive_group()
    g.add_argument("--smoke", action="store_true",
                   help="CI subset: 2 ladders, one size (default)")
    g.add_argument("--full", action="store_true",
                   help="all f32-high ladders + solve/refine/uncompressed "
                        "+ mutation self-test")
    ap.add_argument("--selftest", action="store_true",
                    help="also run the seeded-mutation self-test")
    ap.add_argument("--json", metavar="OUT",
                    help="write the schema'd violation report here")
    args = ap.parse_args(argv)
    mode = "full" if args.full else "smoke"

    _ensure_devices()
    from repro.audit.report import build_report
    results = _run(mode, args.selftest)
    report = build_report(mode, results)

    for res in results:
        mark = "ok " if res.ok else "FAIL"
        print(f"[{mark}] {res.name:16s} {res.target}")
        for v in res.violations:
            print(f"       {v}")
    s = report["summary"]
    print(f"-- {s['checks']} checks, {s['errors']} errors, "
          f"{s['warns']} warnings ({mode})")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"-- report written to {args.json}")
    return 1 if s["errors"] else 0


if __name__ == "__main__":
    sys.exit(main())
