"""Repo lint pack: AST rules for the layering invariants the audits rely on.

Five rules, each protecting an invariant that the runtime checks in this
package *assume* rather than verify:

* **plan-trace-free** — ``core/plan.py`` must not import jax. The whole
  audit design rests on plans being static pure-numpy tables that can be
  compared to traced programs; a jax import means plan construction could
  itself trace and the comparison becomes circular.
* **db-stdlib-only** — ``tune/db.py`` must not import jax (module level
  or inline). The CI perf gates (``tools/perf_gate.py``) import it from a
  bare-venv context; a device-runtime import there breaks every gate.
* **kernel-dtype-literal** — ``kernels/*.py`` must not hardcode narrow
  ladder dtypes (``jnp.float16`` / ``jnp.bfloat16`` / ``jnp.int8``) or
  magic range constants (``65504``); they come from
  ``repro.core.precision.DTYPES`` / ``RMAX`` so a ladder change (f8)
  lands in one table, not a grep hunt. f32/f64 literals are fine —
  accumulators and routing are genuinely fixed-width.
* **search-injected-timer** — ``tune/search.py`` may touch the wall
  clock only inside the injected-timer default (``timeit``); everywhere
  else timing flows through the ``timer`` parameter, and RNG must be
  seeded. This keeps the autotuner replayable in tests with a fake timer.
* **serve-public-surface** — in-repo callers outside ``src/repro/serve/``
  (the rest of ``src/repro``, ``benchmarks/``, ``examples/``) import
  serving names only from ``repro.serve``, never from its submodules
  (``repro.serve.engine`` etc.). The serve ``__init__`` is the curated
  public API; submodule layout is free to change between PRs only while
  nothing outside the package depends on it. ``tests/`` are exempt —
  white-box tests may reach into internals.

Suppress a single line with ``# audit: allow(<rule>)``.

Stdlib-only (``ast`` + ``re``): runs in the CI lint job next to ruff,
before any venv has jax installed.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path

from repro.audit.report import CheckResult, Violation

#: narrow dtype attribute names banned in kernels/ (wide ones routed)
_NARROW_ATTRS = {"float16", "bfloat16", "int8", "float8_e4m3fn",
                 "float8_e5m2"}
#: magic f16 range constant (RMAX["f16"])
_MAGIC_CONSTS = {65504, 65504.0}

_ALLOW_RE = re.compile(r"#\s*audit:\s*allow\(([a-z0-9-]+)\)")

RULES = ("plan-trace-free", "db-stdlib-only", "kernel-dtype-literal",
         "search-injected-timer", "serve-public-surface")

#: repro.serve submodules that are implementation layout, not API
_SERVE_SUBMODULES = {"engine", "scheduler", "metrics", "frontend",
                     "options"}


def repo_root() -> Path:
    """``src/``'s parent — the directory holding ``pyproject.toml``."""
    return Path(__file__).resolve().parents[3]


def _allows(source_lines: list[str]) -> dict[int, set[str]]:
    out: dict[int, set[str]] = {}
    for i, line in enumerate(source_lines, 1):
        for m in _ALLOW_RE.finditer(line):
            out.setdefault(i, set()).add(m.group(1))
    return out


class _Lint:
    def __init__(self, path: Path, rel: str):
        self.path, self.rel = path, rel
        src = path.read_text()
        self.tree = ast.parse(src, filename=str(path))
        self.allows = _allows(src.splitlines())
        self.viols: list[Violation] = []

    def flag(self, rule: str, node: ast.AST, msg: str):
        line = getattr(node, "lineno", 0)
        if rule in self.allows.get(line, ()):
            return
        self.viols.append(Violation(rule, self.rel, msg,
                                    path=self.rel, line=line))

    # -- rule bodies -------------------------------------------------------
    def no_jax_imports(self, rule: str, why: str):
        for node in ast.walk(self.tree):
            mods = ()
            if isinstance(node, ast.Import):
                mods = tuple(a.name for a in node.names)
            elif isinstance(node, ast.ImportFrom) and node.module:
                mods = (node.module,)
            for mod in mods:
                if mod == "jax" or mod.startswith("jax."):
                    self.flag(rule, node,
                              f"imports {mod} at line {node.lineno}; {why}")

    def no_narrow_dtype_literals(self):
        for node in ast.walk(self.tree):
            if (isinstance(node, ast.Attribute)
                    and isinstance(node.value, ast.Name)
                    and node.value.id in ("jnp", "np", "jax")
                    and node.attr in _NARROW_ATTRS):
                self.flag(
                    "kernel-dtype-literal", node,
                    f"hardcoded {node.value.id}.{node.attr} at line "
                    f"{node.lineno}; use repro.core.precision.DTYPES so "
                    "ladder growth lands in one table")
            elif (isinstance(node, ast.Constant)
                    and type(node.value) in (int, float)
                    and node.value in _MAGIC_CONSTS):
                self.flag(
                    "kernel-dtype-literal", node,
                    f"magic range constant {node.value} at line "
                    f"{node.lineno}; use repro.core.precision.RMAX")

    def serve_surface_only(self):
        why = ("serving names are public only via repro.serve "
               "(docs/SERVING.md); submodule layout is private")
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.name.startswith("repro.serve."):
                        self.flag("serve-public-surface", node,
                                  f"imports {a.name} at line "
                                  f"{node.lineno}; {why}")
            elif isinstance(node, ast.ImportFrom) and node.module:
                if node.module.startswith("repro.serve."):
                    self.flag("serve-public-surface", node,
                              f"imports from {node.module} at line "
                              f"{node.lineno}; {why}")
                elif node.module == "repro.serve":
                    for a in node.names:
                        if a.name in _SERVE_SUBMODULES:
                            self.flag(
                                "serve-public-surface", node,
                                f"from repro.serve import {a.name} at "
                                f"line {node.lineno} reaches the "
                                f"submodule; {why}")

    def timer_confined(self):
        stack: list[str] = []

        def visit(node):
            is_fn = isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
            if is_fn:
                stack.append(node.name)
            if (isinstance(node, ast.Attribute)
                    and isinstance(node.value, ast.Name)
                    and node.value.id in ("time", "datetime")
                    and "timeit" not in stack):
                self.flag(
                    "search-injected-timer", node,
                    f"wall-clock access {node.value.id}.{node.attr} at "
                    f"line {node.lineno} outside the injected-timer "
                    "default; route timing through the timer parameter")
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "default_rng"
                    and not node.args and not node.keywords):
                self.flag(
                    "search-injected-timer", node,
                    f"unseeded default_rng() at line {node.lineno}; "
                    "tuning runs must be replayable — pass a seed")
            for child in ast.iter_child_nodes(node):
                visit(child)
            if is_fn:
                stack.pop()

        visit(self.tree)


def lint_repo(root: Path | None = None) -> CheckResult:
    """Run all four rules; returns one CheckResult for the lint pack."""
    root = Path(root) if root else repo_root()
    src = root / "src" / "repro"
    viols: list[Violation] = []

    def run(relpath: str, fn, *args):
        p = src / relpath
        rel = f"src/repro/{relpath}"
        if not p.exists():
            viols.append(Violation("lint-missing-file", rel,
                                   f"{rel} not found", severity="warn"))
            return
        lint = _Lint(p, rel)
        fn(lint, *args)
        viols.extend(lint.viols)

    run("core/plan.py", _Lint.no_jax_imports, "plan-trace-free",
        "plans must stay static pure-numpy tables")
    run("tune/db.py", _Lint.no_jax_imports, "db-stdlib-only",
        "CI perf gates import this from a jax-free venv")
    for kp in sorted((src / "kernels").glob("*.py")):
        run(f"kernels/{kp.name}", _Lint.no_narrow_dtype_literals)
    run("tune/search.py", _Lint.timer_confined)
    # serve-public-surface sweeps everything outside the serve package
    # itself; tests/ stay exempt (white-box tests reach into internals)
    sweep = [p for p in sorted(src.rglob("*.py"))
             if "serve" not in p.relative_to(src).parts[:1]]
    for base in (root / "benchmarks", root / "examples"):
        sweep.extend(sorted(base.glob("*.py")))
    for p in sweep:
        rel = str(p.relative_to(root))
        lint = _Lint(p, rel)
        lint.serve_surface_only()
        viols.extend(lint.viols)
    return CheckResult("lint", "src/repro", viols)


if __name__ == "__main__":      # CI lint job: no jax in that venv
    import sys
    res = lint_repo()
    for v in res.violations:
        print(v)
    print(f"lint pack: {len(res.violations)} finding(s)")
    sys.exit(0 if res.ok else 1)
