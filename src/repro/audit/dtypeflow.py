"""Jaxpr dtype-flow analysis: recover *value* precision from f32 graphs.

The CPU execution path (``ops.resolve_impl`` -> the jnp oracles) keeps
every array in its f32/f64 container and applies the ladder's precision
as VALUE-level rounding: ``storage_round`` / ``_round_tiles`` cast
through the narrow dtype (``x.astype(f16).astype(f32)``) or, for int8,
round against a per-block scale. A naive dtype census of such a jaxpr
therefore sees only f32xf32 dots. This walker recovers the effective
precision by propagating a *precision tag* along def-use chains:

* ``convert_element_type`` to a strictly narrower dtype tags the value
  with that dtype (the rounding event); converting back up keeps the tag.
* ``round_nearest_even`` (the jnp.round in int8 quantization) tags int8.
* pure data movement (slice/reshape/transpose/broadcast/concatenate/
  gather/squeeze/rev/copy/pad) joins operand tags (coarsest wins).
* ``mul``/``div``/``max``/``min`` where one operand has strictly fewer
  elements than the other (a broadcast quantization scale or clip bound)
  preserves the big operand's tag — this is what keeps the per-block
  scale multiply in ``_round_tiles`` from washing out the tag.
* every other computation produces a wide (container-precision) value.

The effective precision of a ``dot_general`` is then the coarsest
effective precision among its operands — exactly the number the
:class:`~repro.core.plan.PrecisionPlan` assigns per tile, which
:mod:`repro.audit.conformance` reconciles.

The walker also recurses through ``pjit``/``scan``/``while``/``cond``/
``shard_map`` call primitives (both ClosedJaxpr and raw Jaxpr params —
shard_map carries a raw Jaxpr) and records collective sites with their
wire dtype for the distributed conformance check.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.dtypes import BYTES

#: precision tags the walker tracks (ladder names). f8 variants ride
#: along so a future f8 ladder audits without touching the walker.
_TAGGABLE = ("int8", "f8e4m3", "f8e5m2", "f16", "bf16", "f32", "f64")

#: np dtype name -> ladder name
_NP_TO_LADDER = {"int8": "int8", "float16": "f16", "bfloat16": "bf16",
                 "float32": "f32", "float64": "f64",
                 "float8_e4m3fn": "f8e4m3", "float8_e5m2": "f8e5m2"}

#: primitives that move data without changing values: tag passes through
_PASSTHROUGH = {
    "slice", "dynamic_slice", "dynamic_update_slice", "squeeze", "reshape",
    "transpose", "broadcast_in_dim", "concatenate", "rev", "copy", "gather",
    "scatter", "pad", "select_n", "stop_gradient", "expand_dims",
}

#: elementwise ops where a broadcast small operand (quant scale / clip
#: bound) must not wash out the big operand's tag
_SCALE_OPS = {"mul", "div", "max", "min", "clamp"}

#: call primitives whose params carry sub-jaxprs to recurse into
_CALL_PRIMS = {"pjit", "closed_call", "core_call", "custom_jvp_call",
               "custom_vjp_call", "custom_vjp_call_jaxpr", "remat",
               "checkpoint", "scan", "while", "cond", "shard_map"}

_COLLECTIVE_PRIMS = {"all_gather", "psum", "psum2", "ppermute",
                     "all_to_all", "reduce_scatter", "psum_scatter"}


def ladder_name(dtype) -> str:
    """Ladder name of a concrete np/jnp dtype (container alphabet)."""
    return _NP_TO_LADDER.get(np.dtype(dtype).name, np.dtype(dtype).name)


def _width(name: str) -> int:
    return BYTES.get(name, 8)


def coarsest(a: str, b: str) -> str:
    """The lower-precision of two ladder names (byte width, int8 lowest)."""
    if a == b:
        return a
    wa, wb = _width(a), _width(b)
    if wa != wb:
        return a if wa < wb else b
    # same width (f16 vs bf16): neither is finer; pick deterministically
    return min(a, b)


@dataclasses.dataclass
class DotSite:
    """One dot_general with effective operand precisions."""

    lhs_name: str           # effective precision of the lhs value
    rhs_name: str
    eff_name: str           # coarsest of the two = the GEMM's precision
    flops: float
    out_shape: tuple


@dataclasses.dataclass
class RoundEvent:
    """One value-rounding event (convert-to-narrower or int8 round)."""

    name: str               # target precision
    elems: int              # elements rounded
    prev: str | None        # tag the value carried before (double-round)


@dataclasses.dataclass
class CollectiveSite:
    """One collective with its wire dtype (container of the operand)."""

    prim: str               # all_gather / psum / ...
    wire: str               # np dtype name on the wire: uint16, int8, ...
    shape: tuple            # operand shape


@dataclasses.dataclass
class FlowResult:
    dots: list
    rounds: list
    collectives: list
    promotions: list        # (src_name, dst_name, elems) widening converts

    def dot_flops_by_name(self) -> dict:
        out: dict[str, float] = {}
        for d in self.dots:
            out[d.eff_name] = out.get(d.eff_name, 0.0) + d.flops
        return out

    def round_elems_by_name(self) -> dict:
        out: dict[str, int] = {}
        for r in self.rounds:
            out[r.name] = out.get(r.name, 0) + r.elems
        return out

    def double_rounds(self) -> list:
        """Incommensurate narrow->narrow re-rounds (f16<->bf16): a value
        already on one 16-bit grid re-rounded onto the other loses bits
        both ways; no ladder in PAPER_CONFIGS produces this chain."""
        bad = []
        for r in self.rounds:
            if r.prev and {r.prev, r.name} == {"f16", "bf16"}:
                bad.append(r)
        return bad


def _aval_elems(var) -> int:
    try:
        return int(np.prod(var.aval.shape, dtype=np.int64))
    except Exception:
        return 0


def _dot_flops(eqn) -> float:
    (contract, _), _ = eqn.params["dimension_numbers"]
    out = eqn.outvars[0].aval
    lhs = eqn.invars[0].aval
    k = 1
    for d in contract:
        k *= lhs.shape[d]
    return 2.0 * float(np.prod(out.shape, dtype=np.int64)) * k


class _Walker:
    def __init__(self):
        self.res = FlowResult([], [], [], [])

    # tags: dict var -> ladder name (only set when narrower than container)
    def walk(self, jaxpr, tags=None):
        tags = dict(tags or {})

        def tag_of(v):
            if hasattr(v, "val"):       # Literal
                return ladder_name(np.asarray(v.val).dtype)
            t = tags.get(v)
            if t is not None:
                return t
            return ladder_name(v.aval.dtype)

        for eqn in jaxpr.eqns:
            prim = eqn.primitive.name
            if prim == "convert_element_type":
                src_v = eqn.invars[0]
                src = tag_of(src_v)
                dst = ladder_name(eqn.params["new_dtype"])
                out = eqn.outvars[0]
                container = ladder_name(src_v.aval.dtype)
                floats = (np.issubdtype(np.dtype(src_v.aval.dtype),
                                        np.floating)
                          and dst in _TAGGABLE and dst != "int8")
                if floats and _width(dst) < _width(container):
                    # precision-losing float convert: a rounding event
                    prev = src if _width(src) <= 2 and src != dst else None
                    self.res.rounds.append(
                        RoundEvent(dst, _aval_elems(out), prev))
                    tags[out] = dst
                elif dst == "int8" and np.issubdtype(
                        np.dtype(src_v.aval.dtype), np.floating):
                    # float -> int8 container cast. The rounding already
                    # happened at the round prim (quant_int8); an astype
                    # of an int8-tagged value is the dequant chain, not a
                    # second round.
                    if src != "int8":
                        self.res.rounds.append(
                            RoundEvent("int8", _aval_elems(out), None))
                    tags[out] = "int8"
                elif floats and _width(dst) > _width(container):
                    # widening float convert: value keeps its tag
                    self.res.promotions.append(
                        (container, dst, _aval_elems(out)))
                    if src in _TAGGABLE and _width(src) < _width(dst):
                        tags[out] = src
                elif src in _TAGGABLE and _width(src) < _width(dst):
                    # int8 container widening back to float, and
                    # same-width converts: tag rides along
                    tags[out] = src
            elif prim == "round_nearest_even" or prim == "round":
                # jnp.round: only reached by int8 per-block quantization
                out = eqn.outvars[0]
                src = tag_of(eqn.invars[0])
                prev = src if _width(src) <= 2 else None
                self.res.rounds.append(
                    RoundEvent("int8", _aval_elems(out), prev))
                tags[out] = "int8"
            elif prim == "dot_general":
                ln = tag_of(eqn.invars[0])
                rn = tag_of(eqn.invars[1])
                self.res.dots.append(DotSite(
                    ln, rn, coarsest(ln, rn), _dot_flops(eqn),
                    tuple(eqn.outvars[0].aval.shape)))
            elif prim in _COLLECTIVE_PRIMS:
                op = eqn.invars[0]
                # jax names the multi-operand psum primitive "psum2"
                base = "psum" if prim.startswith("psum") else prim
                self.res.collectives.append(CollectiveSite(
                    base, np.dtype(op.aval.dtype).name,
                    tuple(op.aval.shape)))
                for ov, iv in zip(eqn.outvars, eqn.invars):
                    t = tags.get(iv)
                    if t is not None:
                        tags[ov] = t
            elif prim in _PASSTHROUGH:
                tin = [tags[v] for v in eqn.invars
                       if not hasattr(v, "val") and v in tags]
                if tin and len(tin) == sum(
                        1 for v in eqn.invars
                        if not hasattr(v, "val")
                        and np.issubdtype(np.dtype(v.aval.dtype),
                                          np.floating)):
                    t = tin[0]
                    for u in tin[1:]:
                        t = coarsest(t, u)
                    for ov in eqn.outvars:
                        tags[ov] = t
                elif len(tin) == 1 and prim in ("dynamic_slice", "slice",
                                                "reshape", "transpose",
                                                "broadcast_in_dim",
                                                "squeeze", "rev", "copy",
                                                "expand_dims", "gather"):
                    # single-array movement: index operands don't count
                    for ov in eqn.outvars:
                        tags[ov] = tin[0]
            elif prim in _SCALE_OPS:
                sized = [(0 if hasattr(v, "val") else _aval_elems(v), k)
                         for k, v in enumerate(eqn.invars)]
                mx = max(e for e, _ in sized)
                big = [k for e, k in sized if e == mx]
                if len(big) == 1 and eqn.invars[big[0]] in tags:
                    tags[eqn.outvars[0]] = tags[eqn.invars[big[0]]]
            elif prim in _CALL_PRIMS or any(
                    self._is_jaxpr(v) for v in eqn.params.values()):
                self._recurse(eqn, tags, tag_of)
            # everything else: fresh wide value, no tag

        return tags

    @staticmethod
    def _is_jaxpr(v):
        return hasattr(v, "jaxpr") or hasattr(v, "eqns")

    def _sub_jaxprs(self, params):
        for v in params.values():
            if hasattr(v, "jaxpr"):         # ClosedJaxpr
                yield v.jaxpr
            elif hasattr(v, "eqns"):        # raw Jaxpr (shard_map)
                yield v
            elif isinstance(v, (tuple, list)):
                for u in v:
                    if hasattr(u, "jaxpr"):
                        yield u.jaxpr
                    elif hasattr(u, "eqns"):
                        yield u

    def _recurse(self, eqn, tags, tag_of):
        subs = list(self._sub_jaxprs(eqn.params))
        for sub in subs:
            sub_tags = {}
            # map caller tags onto callee invars positionally where the
            # arity lines up (pjit/shard_map); otherwise walk untagged —
            # rounding events inside are still collected either way.
            consts = getattr(sub, "constvars", [])
            nin = len(sub.invars)
            args = eqn.invars[-nin:] if len(eqn.invars) >= nin else []
            for iv, av in zip(sub.invars, args):
                if not hasattr(av, "val") and av in tags:
                    sub_tags[iv] = tags[av]
            del consts
            out_tags = self.walk(sub, sub_tags)
            if len(sub.outvars) == len(eqn.outvars):
                for ov, sov in zip(eqn.outvars, sub.outvars):
                    if not hasattr(sov, "val") and sov in out_tags:
                        tags[ov] = out_tags[sov]


def analyze(closed_jaxpr) -> FlowResult:
    """Walk a ClosedJaxpr (or raw Jaxpr) and return the flow census."""
    w = _Walker()
    jaxpr = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    w.walk(jaxpr)
    return w.res


def trace(fn, *args, **kwargs) -> FlowResult:
    """jax.make_jaxpr + analyze in one step (args are ShapeDtypeStructs
    or concrete arrays; nothing is executed)."""
    import jax
    return analyze(jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args))
