"""Sharding rules: parameters, optimizer state, batches, caches.

DP(+FSDP) over 'data' (+ 'pod'), TP over 'model', EP over 'model' for
MoE experts, SP via seq-sharded residuals (Sharder). Rules are by leaf
path + shape with divisibility guards; anything unmatched is replicated
(correct, just not memory-optimal — the dry-run memory analysis catches
regressions).
"""
from __future__ import annotations

import re

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig, Sharder


def _path_str(path):
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def _axis_size(mesh, axis) -> int:
    if isinstance(axis, tuple):
        s = 1
        for a in axis:
            s *= mesh.shape[a]
        return s
    return mesh.shape[axis]


def _div(n, mesh, axis):
    return axis is not None and n % _axis_size(mesh, axis) == 0


def make_sharder(mesh, *, multi_pod: bool, batch: int,
                 layout: str = "tp") -> Sharder:
    """layout='tp' : data-parallel over (pod,)data, TP/EP over model.
    layout='ddp': both axes are data parallelism + ZeRO-3 (the perf-note-B3
    winner for small recurrent archs whose time-scan forbids sequence
    sharding — TP buys nothing there)."""
    batch_axes = pick_batch_axes(batch, mesh, multi_pod, layout)
    if layout == "ddp":
        return Sharder(enabled=True, batch_axes=batch_axes,
                       model_axis=None, fsdp_axis="fsdp-all", mesh=mesh)
    return Sharder(enabled=True, batch_axes=batch_axes, model_axis="model",
                   fsdp_axis="data", mesh=mesh)


def pick_batch_axes(batch: int, mesh, multi_pod: bool,
                    layout: str = "tp"):
    """Greedily assign mesh axes to the batch dim while they divide it
    (long_500k's batch=1 ends up fully replicated)."""
    if layout == "ddp":
        cands = (("pod", "data", "model") if multi_pod
                 else ("data", "model"))
    else:
        cands = ("pod", "data") if multi_pod else ("data",)
    axes = []
    rem = batch
    for a in cands:
        s = mesh.shape[a]
        if rem % s == 0 and rem >= s:
            axes.append(a)
            rem //= s
    return tuple(axes)


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------
_RULES = [
    # (regex on path, matcher(shape) -> PartitionSpec or None)
    (r"moe/(w_in|w_gate|w_out)$", "moe_expert"),
    (r"(embed)$", "embed"),
    (r"(lm_head)$", "lm_head"),
    (r"(w_in|w_gate|wq|wk|wv|wr|wk|wv|wg|ck|w_uq|w_uk|w_uv)$", "d_to_f"),
    (r"(w_out|wo|cv)$", "f_to_d"),
    (r"(router|w_dkv|w_kr|w_dq|w_lora_a|patch_proj|cr)$", "d_only"),
    (r"(shared/w_in|shared/w_gate)$", "d_to_f"),
    (r"(shared/w_out)$", "f_to_d"),
]


def param_spec(path, leaf, cfg: ModelConfig, mesh, layout: str = "tp") -> P:
    """PartitionSpec for one parameter leaf (shape may have a leading
    stacked-layers dim)."""
    ps = _path_str(path)
    shape = leaf.shape
    if layout == "ddp":
        # pure ZeRO-3: shard one big dim over ALL mesh axes, no TP
        dall = tuple(mesh.axis_names)
        if leaf.ndim >= 2:
            for dim in (leaf.ndim - 2, leaf.ndim - 1):
                if _div(shape[dim], mesh, dall):
                    spec = [None] * leaf.ndim
                    spec[dim] = dall
                    return P(*spec)
        return P()
    # FSDP spans the pod axis too on the multi-pod mesh (ZeRO-3 over all
    # 512 chips — the 671B configs need it; see docs/ARCHITECTURE.md)
    d = ("pod", "data") if "pod" in mesh.axis_names else "data"
    m = "model"

    def guard(spec):
        out = []
        for ax, size in zip(spec, shape):
            ok = ax is not None and _div(size, mesh, ax)
            out.append(ax if ok else None)
        return P(*out)

    kind = None
    for rx, k in _RULES:
        if re.search(rx, ps):
            kind = k
            break
    if kind is None or leaf.ndim < 2:
        return P()  # norms, biases, scalars: replicated

    lead = (None,) * (leaf.ndim - 2)
    if kind == "moe_expert":
        # [L, E, din, dout]: EP over model, FSDP over data on din
        lead = (None,) * (leaf.ndim - 3)
        return guard(lead + (m, d, None))
    if kind == "embed":
        if "audio" == cfg.family and leaf.ndim == 3:     # [ncb, V, D]
            return guard((None, m, d))
        return guard((m, d))                              # [V, D]
    if kind == "lm_head":
        if cfg.family == "audio" and leaf.ndim == 3:      # [ncb, D, V]
            return guard((None, d, m))
        return guard((d, m))                              # [D, V]
    if kind == "d_to_f":
        return guard(lead + (d, m))
    if kind == "f_to_d":
        return guard(lead + (m, d))
    if kind == "d_only":
        return guard(lead + (d, None))
    raise AssertionError(kind)


def param_shardings(shapes, cfg: ModelConfig, mesh, layout: str = "tp"):
    """Pytree of NamedSharding matching a pytree of ShapeDtypeStruct."""
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, param_spec(p, l, cfg, mesh,
                                                    layout)),
        shapes)


def opt_state_shardings(opt_shapes, params_shardings, mesh):
    """Optimizer state: moments mirror their parameter's sharding; the
    TreeNewton stats/factors [L, nb, b, b] shard L over data; scalars
    replicated."""
    pflat = {_path_str(p): s for p, s in
             jax.tree_util.tree_flatten_with_path(params_shardings)[0]}

    def one(path, leaf):
        ps = _path_str(path)
        # adam moments: ".../m/<param path>" or ".../v/<param path>"
        mM = re.match(r"^(?:adam/)?(?:m|v)/(.*)$", ps)
        if mM and mM.group(1) in pflat:
            return pflat[mM.group(1)]
        if re.search(r"(stats|factors)/", ps) and leaf.ndim >= 3:
            if leaf.shape[0] % mesh.shape["data"] == 0:
                return NamedSharding(
                    mesh, P("data", *(None,) * (leaf.ndim - 1)))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(one, opt_shapes)


# ---------------------------------------------------------------------------
# batches / caches
# ---------------------------------------------------------------------------
def batch_shardings(batch_shapes, sharder: Sharder, mesh, accum: int = 1):
    lead = (None,) if accum > 1 else ()

    def one(path, leaf):
        rest = (None,) * (leaf.ndim - len(lead) - 1)
        return NamedSharding(mesh, P(*lead, sharder.batch_axes, *rest))

    return jax.tree_util.tree_map_with_path(one, batch_shapes)


def cache_spec(path, leaf, cfg: ModelConfig, sharder: Sharder, mesh) -> P:
    """Serving-cache leaf: [L, B, S, ...]. Shard batch over the batch
    axes and one inner dim over model (KV heads if divisible, else
    head_dim / latent / state-heads), else replicate that dim."""
    key = str(getattr(path[-1], "key", ""))
    b = sharder.batch_axes
    m = sharder.model_axis          # None under the ddp layout
    shape = leaf.shape

    def pick(idx_options):
        spec = [None] * leaf.ndim
        spec[1] = b
        for i in idx_options:
            if _div(shape[i], mesh, m):
                spec[i] = m
                break
        return P(*spec)

    if key in ("k", "v"):            # [L, B, S, KV, hd]
        return pick([3, 4])
    if key == "c":                   # [L, B, S, R]
        return pick([3])
    if key == "kr":                  # [L, B, S, dr]
        return pick([3])
    if key == "s":                   # rwkv [L, B, H, N, N]
        return pick([2])
    if key == "ssm":                 # mamba [L, B, H, N, P]
        return pick([2])
    if key == "conv":                # [L, B, 3, C]
        return pick([3])
    if key in ("x_tm", "x_cm"):      # [L, B, D]
        return pick([2])
    return P(*([None, b] + [None] * (leaf.ndim - 2)))


def cache_shardings(cache_shapes, cfg: ModelConfig, sharder: Sharder, mesh):
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(
            mesh, cache_spec(p, l, cfg, sharder, mesh)), cache_shapes)
