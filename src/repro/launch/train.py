"""Production training driver.

Wires together: arch registry, mesh + sharding rules, microbatched train
step (AdamW or TreeNewton), deterministic restart-safe data pipeline,
async atomic checkpoints, preemption-aware save (SIGTERM hook), and a
step-time heartbeat for straggler detection.

On a real TPU pod this runs under `python -m repro.launch.train --arch
<id> --mesh 16x16`; on this CPU container use --smoke (reduced config,
host mesh or no mesh):

  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --smoke \
      --steps 50 --optimizer tree_newton

Pipeline-parallel seam (docs/ARCHITECTURE.md, "Model and training
integrations"): stages would slot in here as an
outer scan over stage groups; the step function and sharding rules are
stage-agnostic by construction.
"""
from __future__ import annotations

import argparse
import signal
import sys
import time

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro import configs
from repro.data import Prefetcher, SyntheticLM
from repro.launch import sharding as SH
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.optim import AdamWConfig, TreeNewtonConfig
from repro.train import (TrainConfig, init_state, make_train_step,
                         reshape_for_accum)


class Heartbeat:
    """Step-time monitor: flags stragglers (steps slower than k x the
    running median) — on a pod this feeds the controller's restart
    policy; here it logs."""

    def __init__(self, factor=3.0):
        self.times = []
        self.factor = factor

    def beat(self, dt):
        import statistics
        self.times.append(dt)
        if len(self.times) >= 8:
            med = statistics.median(self.times[-50:])
            if dt > self.factor * med:
                print(f"[heartbeat] straggler step: {dt * 1e3:.0f}ms vs "
                      f"median {med * 1e3:.0f}ms", file=sys.stderr)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=configs.ARCHS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--optimizer", default="adamw",
                    choices=("adamw", "tree_newton"))
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--mesh", default="none",
                    help="none | host(DxM) | 16x16 | 2x16x16")
    ap.add_argument("--layout", default="tp", choices=("tp", "ddp"))
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, smoke=args.smoke)
    if args.smoke:
        cfg = cfg.replace(remat=False)

    # mesh / sharder -------------------------------------------------------
    if args.mesh == "none":
        mesh = None
        from repro.models.common import NO_SHARD as sharder
    elif args.mesh.startswith("host"):
        d, m = (int(x) for x in args.mesh[5:-1].split("x"))
        mesh = make_host_mesh(d, m)
        sharder = SH.make_sharder(mesh, multi_pod=False, batch=args.batch,
                                  layout=args.layout)
    else:
        mesh = make_production_mesh(multi_pod=args.mesh.count("x") == 2)
        sharder = SH.make_sharder(mesh, multi_pod=args.mesh.count("x") == 2,
                                  batch=args.batch, layout=args.layout)

    adam = AdamWConfig(lr=args.lr, warmup=min(20, args.steps // 5),
                       total_steps=args.steps)
    tn = TreeNewtonConfig(adam=adam, block=128, factor_every=20,
                          stats_every=2)
    tcfg = TrainConfig(optimizer=args.optimizer, adam=adam, tree_newton=tn,
                       accum=args.accum)

    state = init_state(jax.random.PRNGKey(0), cfg, tcfg)
    nparams = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"{cfg.name}: {nparams / 1e6:.1f}M params, opt={args.optimizer}, "
          f"mesh={args.mesh}")

    start = 0
    if args.resume and ckpt.latest_step(args.ckpt_dir) is not None:
        state, start = ckpt.restore(args.ckpt_dir, state)
        print(f"resumed at step {start}")

    step_fn = make_train_step(cfg, tcfg, sharder)
    step_fn = jax.jit(step_fn, donate_argnums=(0,))

    data = SyntheticLM(cfg.vocab, args.batch, args.seq, seed=0,
                       n_codebooks=cfg.n_codebooks,
                       n_img_tokens=cfg.n_img_tokens, d_model=cfg.d_model)
    pf = Prefetcher(data, start_step=start)
    hb = Heartbeat()

    # preemption hook: SIGTERM triggers a blocking save before exit -------
    stop = {"now": False}

    def _sigterm(signum, frame):
        stop["now"] = True
    signal.signal(signal.SIGTERM, _sigterm)

    ctx = mesh or _NullCtx()
    handle = None
    with ctx:
        for _ in range(start, args.steps):
            t0 = time.time()
            i, batch = pf.next()
            batch = jax.tree.map(jnp.asarray, batch)
            batch = reshape_for_accum(batch, tcfg.accum)
            state, m = step_fn(state, batch)
            jax.block_until_ready(m["loss"])
            hb.beat(time.time() - t0)
            if (i + 1) % 10 == 0:
                print(f"step {i + 1:5d} loss={float(m['loss']):8.4f} "
                      f"gnorm={float(m['grad_norm']):7.3f} "
                      f"lr={float(m['lr']):.2e}")
            if (i + 1) % args.ckpt_every == 0:
                handle = ckpt.save(args.ckpt_dir, i + 1, state)
            if stop["now"]:
                print("[preempt] SIGTERM — saving and exiting")
                ckpt.save(args.ckpt_dir, i + 1, state, blocking=True)
                break
    if handle:
        handle.wait()
    pf.close()
    print("done")


class _NullCtx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
