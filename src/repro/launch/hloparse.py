"""Optimized-HLO census: exact FLOPs / HBM bytes / collective bytes with
while-loop trip-count scaling.

XLA's HloCostAnalysis counts a while-loop body ONCE regardless of trip
count (verified in tests/test_roofline.py), which silently drops ~L x the
FLOPs of a scanned-layer model. This parser recovers the real totals from
``compiled.as_text()``:

  1. split the module into computations,
  2. find every ``while`` instruction, read its trip count from the
     condition computation's ``constant(N)`` + ``compare(..., LT)``,
  3. propagate execution multipliers (nested loops multiply),
  4. per instruction, accumulate
       * dot FLOPs (2 * prod(batch+m+n) * prod(contracting)),
       * I/O bytes of top-level fusions/dots/custom-calls (HBM-traffic
         proxy: each fusion reads operands and writes outputs once),
       * collective output bytes per op kind.

All numbers are per device (the module is already SPMD-partitioned).
"""
from __future__ import annotations

import dataclasses
import re

from repro.core.dtypes import BYTES as _DTYPE_BYTES  # noqa: F401 (re-export)
from repro.core.dtypes import shape_regex_alternation

_SHAPE_RE = re.compile(
    r"\b(" + shape_regex_alternation() + r")\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(")
# lazy type match: tuple types may contain /*index=N*/ comments, braces,
# and '='; the op is the first bare `word(` after the '='.
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s*\b([\w\-]+)\(")
_CALLED = re.compile(
    r"(?:condition|body|to_apply|calls|called_computations)="
    r"\{?%?([\w.\-]+)")
_TRIP = re.compile(r"constant\((\d+)\)")
_KNOWN_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_elems_bytes(type_str: str):
    elems = bytes_ = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        bytes_ += n * _DTYPE_BYTES[dt]
    return elems, bytes_


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    out_type: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list

    def find(self, name):
        for i in self.instrs:
            if i.name == name:
                return i
        return None


def parse_computations(hlo: str) -> dict:
    comps = {}
    cur = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line or line.lstrip().startswith("//"):
            continue
        if (not line.startswith(" ") and "->" in line
                and line.endswith("{")):
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = Computation(m.group(1), [])
                comps[cur.name] = cur
                continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if m:
            cur.instrs.append(Instr(m.group(1), m.group(3).strip(),
                                    m.group(2).strip(), line.strip()))
    return comps


def _entry_name(hlo: str, comps: dict) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
    if m and m.group(1) in comps:
        return m.group(1)
    # fallback: computation not referenced by anyone
    called = set()
    for c in comps.values():
        for i in c.instrs:
            for cc in _CALLED.findall(i.line):
                called.add(cc)
    for name in comps:
        if name not in called:
            return name
    return next(iter(comps))


def _trip_count_from_while(instr: Instr, comps: dict) -> int:
    """Prefer XLA's own annotation; fall back to the condition parse."""
    m = _KNOWN_TRIP.search(instr.line)
    if m:
        return int(m.group(1))
    mc = re.search(r"condition=%?([\w.\-]+)", instr.line)
    if mc and mc.group(1) in comps:
        return _trip_count(comps[mc.group(1)])
    return 1


def _trip_count(cond: Computation) -> int:
    """Scan-style condition: compare(iter, constant(N)), direction=LT."""
    consts = {}
    for i in cond.instrs:
        m = _TRIP.search(i.line)
        if m:
            consts[i.name] = int(m.group(1))
    for i in cond.instrs:
        if i.op == "compare" and "direction=LT" in i.line:
            for cname, val in consts.items():
                if cname in i.line:
                    return val
    # single constant in a tiny condition — take it
    if len(consts) == 1:
        return next(iter(consts.values()))
    return 1


def _multipliers(comps: dict, entry: str) -> dict:
    """Execution multiplier per computation (nested whiles multiply)."""
    mult = {name: 0 for name in comps}
    mult[entry] = 1

    def visit(name, m):
        if mult.get(name, 0) >= m and name != entry:
            pass
        mult[name] = max(mult.get(name, 0), m)
        comp = comps[name]
        for i in comp.instrs:
            called = _CALLED.findall(i.line)
            if i.op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", i.line)
                mc = re.search(r"condition=%?([\w.\-]+)", i.line)
                body = mb.group(1) if mb else None
                cond = mc.group(1) if mc else None
                trips = _trip_count_from_while(i, comps)
                if body in comps:
                    visit(body, m * max(trips, 1))
                if cond in comps:
                    visit(cond, m * max(trips, 1))
            else:
                for cc in called:
                    if cc in comps:
                        visit(cc, m)

    visit(entry, 1)
    return mult


_DOT_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_TYPES = re.compile(
    r"\(((?:%?[\w.\-]+(?:,\s*)?)+)\)")


def _dot_info(instr: Instr, comp: Computation):
    """``(flops, lhs_dtype, rhs_dtype)`` for a dot instruction.

    FLOPs = 2 * prod(output dims) * prod(contracting dims of lhs); the
    operand dtypes feed the per-dtype-pair classification the precision
    auditor reconciles against the plan.
    """
    out_elems, _ = _shape_elems_bytes(instr.out_type)
    mc = _DOT_CONTRACT.search(instr.line)
    args = re.search(r"\b" + re.escape(instr.op) + r"\(([^)]*)\)",
                     instr.line)
    contract = 1
    lhs_dt = rhs_dt = None
    if args:
        # newer jaxlib prints typed operands inline: dot(f32[16,128] %a, ...)
        shapes = _SHAPE_RE.findall(args.group(1))
        if not shapes:
            # untyped operand list: resolve each operand by name lookup
            for a in args.group(1).split(",")[:2]:
                src = comp.find(a.strip().lstrip("%"))
                if src is not None:
                    m3 = _SHAPE_RE.search(src.out_type)
                    if m3:
                        shapes.append(m3.groups())
        if shapes:
            lhs_dt = shapes[0][0]
            if len(shapes) > 1:
                rhs_dt = shapes[1][0]
            if mc:
                dims = [int(d) for d in shapes[0][1].split(",") if d]
                for ci in mc.group(1).split(","):
                    if ci:
                        contract *= dims[int(ci)]
    return 2.0 * out_elems * contract, lhs_dt, rhs_dt


def _dot_flops(instr: Instr, comp: Computation) -> float:
    return _dot_info(instr, comp)[0]


# ops whose I/O we count as HBM traffic. Pure layout/expansion ops
# (reshape/broadcast/convert/iota/...) are excluded: on TPU they fuse
# into consumers; the CPU HLO we parse leaves them unfused, which would
# inflate the proxy several-fold. The result is still an upper bound on
# TPU HBM traffic (documented in docs/ARCHITECTURE.md, "Census and roofline").
_MEM_OPS = {"fusion", "dot", "custom-call", "convolution", "copy",
            "dynamic-slice", "dynamic-update-slice", "gather", "scatter",
            "sort",
            "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
            "collective-permute"}


def _operand_bytes(instr: Instr, comp: Computation) -> float:
    args = re.search(r"\b" + re.escape(instr.op) + r"\(([^)]*)\)",
                     instr.line)
    if not args:
        return 0.0
    if _SHAPE_RE.search(args.group(1)):
        # typed operand list: sum the inline shapes directly
        _, b = _shape_elems_bytes(args.group(1))
        return float(b)
    total = 0.0
    for a in args.group(1).split(","):
        a = a.strip().lstrip("%")
        src = comp.find(a)
        if src is not None:
            _, b = _shape_elems_bytes(src.out_type)
            total += b
    return total


def census(hlo: str) -> dict:
    comps = parse_computations(hlo)
    entry = _entry_name(hlo, comps)
    mult = _multipliers(comps, entry)

    flops = 0.0
    hbm_bytes = 0.0
    coll = {k: {"count": 0.0, "bytes": 0.0} for k in COLLECTIVES}
    dot_by_dtype: dict[str, float] = {}
    coll_by_dtype: dict[str, float] = {}
    loops = []
    for name, comp in comps.items():
        m = mult.get(name, 0)
        if m == 0:
            continue
        for i in comp.instrs:
            if i.op == "dot":
                f, ldt, rdt = _dot_info(i, comp)
                flops += m * f
                key = f"{ldt or 'unknown'}x{rdt or ldt or 'unknown'}"
                dot_by_dtype[key] = dot_by_dtype.get(key, 0.0) + m * f
            if i.op in COLLECTIVES or i.op.startswith(
                    tuple(c + "-start" for c in COLLECTIVES)):
                base = i.op.replace("-start", "")
                if base in coll:
                    _, b = _shape_elems_bytes(i.out_type)
                    coll[base]["count"] += m
                    coll[base]["bytes"] += m * b
                    for dt, dims in _SHAPE_RE.findall(i.out_type):
                        ne = 1
                        for d in dims.split(","):
                            if d:
                                ne *= int(d)
                        coll_by_dtype[dt] = (coll_by_dtype.get(dt, 0.0)
                                             + m * ne * _DTYPE_BYTES[dt])
            if i.op in _MEM_OPS and not i.op.endswith("-done"):
                _, ob = _shape_elems_bytes(i.out_type)
                hbm_bytes += m * (ob + _operand_bytes(i, comp))
            if i.op == "while":
                loops.append((i.name, _trip_count_from_while(i, comps)))
    return {"flops": flops, "hbm_bytes": hbm_bytes,
            "collectives": coll, "loops": sorted(set(loops)),
            "n_computations": len(comps),
            "dot_flops_by_dtype": dot_by_dtype,
            "collective_bytes_by_dtype": coll_by_dtype}
