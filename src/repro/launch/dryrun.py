"""Multi-pod dry-run (assignment §MULTI-POD DRY-RUN).

For every (architecture x input-shape x mesh) cell:
  * build the production mesh ((16,16) or (2,16,16) placeholder devices),
  * abstract-init params / optimizer state / caches (ShapeDtypeStruct),
  * jit the right step (train_step / prefill_step / serve_step) with
    explicit in/out shardings,
  * .lower().compile() — success proves the distribution config is
    coherent; failures are bugs,
  * record memory_analysis(), cost_analysis(), and per-collective bytes
    parsed from the optimized HLO into experiments/dryrun/<cell>.json
    (consumed by benchmarks/roofline.py; docs/ARCHITECTURE.md,
    "Census and roofline").

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b \
      --shape train_4k [--multi-pod] [--kfac] [--out experiments/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
from __future__ import annotations

# The VERY FIRST action before any jax-touching import: the dry-run (and
# only the dry-run) needs 512 placeholder devices (assignment step 0).
import os  # noqa: E402
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import functools
import json
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch import sharding as SH
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.optim import AdamWConfig, TreeNewtonConfig
from repro.serve import prefill_step, serve_step
from repro.train import TrainConfig, make_train_step

# ---------------------------------------------------------------------------
# cell construction  (the collective/FLOP census lives in hloparse.py —
# it attributes ops to computations and scales by while-loop trip counts)
# ---------------------------------------------------------------------------
def build_cell(arch: str, shape_name: str, *, multi_pod: bool,
               kfac: bool = False, accum: int | None = None,
               layout: str = "tp"):
    """Returns (lower_fn,) — a thunk that lowers+compiles and returns the
    (lowered, compiled) pair."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = configs.get_config(arch)
    shape = configs.SHAPES[shape_name]
    sharder = SH.make_sharder(mesh, multi_pod=multi_pod,
                              batch=shape.global_batch, layout=layout)

    rng = jax.random.PRNGKey(0)
    p_shapes = jax.eval_shape(lambda k: T.init_params(k, cfg), rng)
    p_shard = SH.param_shardings(p_shapes, cfg, mesh, layout)

    if shape.kind == "train":
        accum = accum or SP.pick_accum(cfg, shape, mesh, sharder.batch_axes)
        big = sum(x.size for x in jax.tree.leaves(p_shapes)) > 1e11
        adam = AdamWConfig(state_dtype="bf16" if big else "f32")
        if kfac:
            tn = TreeNewtonConfig(adam=adam, block=512, factor_every=10)
            tcfg = TrainConfig(optimizer="tree_newton", tree_newton=tn,
                               accum=accum)
        else:
            tcfg = TrainConfig(optimizer="adamw", adam=adam, accum=accum)

        from repro.train import init_state
        s_shapes = jax.eval_shape(
            lambda k: init_state(k, cfg, tcfg), rng)
        o_shard = SH.opt_state_shardings(s_shapes["opt"], p_shard, mesh)
        state_shard = {"params": p_shard, "opt": o_shard,
                       "step": NamedSharding(mesh, P())}
        b_struct = SP.train_batch_struct(cfg, shape, accum)
        b_shard = SH.batch_shardings(b_struct, sharder, mesh, accum)
        step = make_train_step(cfg, tcfg, sharder)
        jf = jax.jit(step, in_shardings=(state_shard, b_shard),
                     out_shardings=(state_shard, None),
                     donate_argnums=(0,))
        lower = lambda: jf.lower(s_shapes, b_struct)
        meta = {"accum": accum, "optimizer": tcfg.optimizer,
                "opt_state_dtype": adam.state_dtype}
    elif shape.kind == "prefill":
        b_struct = SP.prefill_batch_struct(cfg, shape)
        b_shard = SH.batch_shardings(b_struct, sharder, mesh)
        c_struct = SP.cache_struct(cfg, shape.global_batch, shape.seq_len)
        c_shard = SH.cache_shardings(c_struct, cfg, sharder, mesh)
        fn = functools.partial(prefill_step, cfg=cfg,
                               sharder=sharder)
        jf = jax.jit(fn, in_shardings=(p_shard, b_shard),
                     out_shardings=(NamedSharding(mesh, P()), c_shard))
        lower = lambda: jf.lower(p_shapes, b_struct)
        meta = {}
    else:  # decode
        c_struct, tok_struct, pos_struct = SP.decode_inputs_struct(cfg,
                                                                   shape)
        c_shard = SH.cache_shardings(c_struct, cfg, sharder, mesh)
        tok_shard = SH.batch_shardings({"t": tok_struct}, sharder,
                                       mesh)["t"]
        fn = functools.partial(serve_step, cfg=cfg, sharder=sharder)
        jf = jax.jit(fn, in_shardings=(p_shard, c_shard, tok_shard,
                                       NamedSharding(mesh, P())),
                     out_shardings=(NamedSharding(mesh, P()), c_shard),
                     donate_argnums=(1,))
        lower = lambda: jf.lower(p_shapes, c_struct, tok_struct, pos_struct)
        meta = {}

    n_params = sum(x.size for x in jax.tree.leaves(p_shapes))
    meta.update({"arch": arch, "shape": shape_name, "layout": layout,
                 "multi_pod": multi_pod, "kfac": kfac,
                 "n_devices": mesh.size, "n_params": int(n_params),
                 "batch_axes": list(sharder.batch_axes)})
    return lower, meta, mesh


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             kfac: bool = False, out_dir: str = "experiments/dryrun",
             hlo_dir: str | None = None, layout: str = "tp"):
    t0 = time.time()
    lower, meta, mesh = build_cell(arch, shape_name, multi_pod=multi_pod,
                                   kfac=kfac, layout=layout)
    with mesh:
        lowered = lower()
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
    rec = dict(meta)
    rec["wall_s"] = round(time.time() - t0, 1)
    rec["memory"] = {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "code_bytes": mem.generated_code_size_in_bytes,
    }
    rec["per_device_bytes"] = (
        mem.argument_size_in_bytes + mem.output_size_in_bytes
        + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    rec["cost"] = {k: v for k, v in cost.items()
                   if k in ("flops", "transcendentals", "bytes accessed")}
    from repro.launch import hloparse
    cen = hloparse.census(hlo)
    rec["census"] = {"flops": cen["flops"], "hbm_bytes": cen["hbm_bytes"],
                     "loops": cen["loops"]}
    rec["collectives"] = cen["collectives"]
    rec["hlo_lines"] = hlo.count("\n")
    os.makedirs(out_dir, exist_ok=True)
    tag = "kfac-" if kfac else ""
    if layout != "tp":
        tag += f"{layout}-"
    name = (f"{tag}{arch}__{shape_name}__"
            f"{'pod2' if multi_pod else 'pod1'}")
    with open(os.path.join(out_dir, name + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    if hlo_dir:
        os.makedirs(hlo_dir, exist_ok=True)
        with open(os.path.join(hlo_dir, name + ".hlo.txt"), "w") as f:
            f.write(hlo)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCHS)
    ap.add_argument("--shape", choices=tuple(configs.SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--kfac", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--hlo-dir", default=None)
    ap.add_argument("--continue-on-error", action="store_true")
    ap.add_argument("--layout", default="tp", choices=("tp", "ddp"))
    args = ap.parse_args()

    cells = ([(args.arch, args.shape, True)] if not args.all
             else configs.cells())
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    ok = fail = skip = 0
    for arch, shp, runnable in cells:
        if not runnable:
            print(f"SKIP  {arch:22s} {shp:12s} (assignment rule)")
            skip += 1
            continue
        for mp in meshes:
            tag = "pod2" if mp else "pod1"
            try:
                rec = run_cell(arch, shp, multi_pod=mp, kfac=args.kfac,
                               out_dir=args.out, hlo_dir=args.hlo_dir,
                               layout=args.layout)
                gb = rec["per_device_bytes"] / 2**30
                print(f"OK    {arch:22s} {shp:12s} {tag}  "
                      f"{gb:7.2f} GiB/dev  flops={rec['cost'].get('flops', 0):.3e}  "
                      f"wall={rec['wall_s']}s")
                ok += 1
            except Exception as e:  # noqa: BLE001
                print(f"FAIL  {arch:22s} {shp:12s} {tag}  "
                      f"{type(e).__name__}: {e}")
                fail += 1
                if not args.continue_on_error:
                    traceback.print_exc()
                    raise
    print(f"\ndry-run summary: {ok} ok, {fail} failed, {skip} skipped")


if __name__ == "__main__":
    main()
