"""Production mesh factories (assignment contract).

Functions, not module-level constants, so importing this module never
touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first init.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """``jax.make_mesh`` with explicit Auto axis types where the jax
    version supports them (``jax.sharding.AxisType`` appeared after
    0.4.x; Auto is the implicit behaviour on older versions)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) data x model single-pod, (2, 16, 16) pod x data x model
    multi-pod — 256 / 512 TPU v5e chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 4, model: int = 2):
    """Small mesh over forced host devices — used by CPU integration
    tests (8 devices) to exercise the exact same sharding rules."""
    return make_mesh((data, model), ("data", "model"))
