"""ShapeDtypeStruct stand-ins for every model input (assignment §dry-run
step 2): weak-type-correct, shardable, zero allocation."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ShapeSpec
from repro.models import attention as attn
from repro.models import mamba2, mla, rwkv6
from repro.models.common import ModelConfig

I32 = jnp.int32
F32 = jnp.float32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_struct(cfg: ModelConfig, shape: ShapeSpec, accum: int = 1):
    B, S = shape.global_batch, shape.seq_len
    lead = (accum, B // accum) if accum > 1 else (B,)
    tok_shape = lead + ((S, cfg.n_codebooks) if cfg.family == "audio"
                        else (S,))
    batch = {"tokens": _sds(tok_shape, I32),
             "labels": _sds(tok_shape, I32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = _sds(lead + (cfg.n_img_tokens, cfg.d_model),
                                     F32)
    return batch


def prefill_batch_struct(cfg: ModelConfig, shape: ShapeSpec):
    B, S = shape.global_batch, shape.seq_len
    tok_shape = (B, S, cfg.n_codebooks) if cfg.family == "audio" else (B, S)
    batch = {"tokens": _sds(tok_shape, I32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = _sds((B, cfg.n_img_tokens, cfg.d_model), F32)
    return batch


def cache_struct(cfg: ModelConfig, batch: int, length: int):
    """Abstract decode caches matching transformer.forward's layout."""
    def build():
        if cfg.family == "rwkv":
            return _stack(lambda: rwkv6.init_rwkv_state(cfg, batch,
                                                        dtype=cfg.adt),
                          cfg.n_layers)
        if cfg.family == "hybrid":
            k = cfg.attn_every or cfg.n_layers
            n_apps = max(cfg.n_layers // k, 1)
            return {
                "mamba": _stack(lambda: mamba2.init_mamba_state(
                    cfg, batch, dtype=cfg.adt), cfg.n_layers),
                "attn": _stack(lambda: _mk_kv(cfg, batch, length), n_apps),
            }
        out = {}
        if cfg.family == "moe" and cfg.moe_first_dense:
            out["dense"] = _stack(lambda: _mk_kv(cfg, batch, length),
                                  cfg.moe_first_dense)
        n_main = cfg.n_layers - (cfg.moe_first_dense
                                 if cfg.family == "moe" else 0)
        out["main"] = _stack(lambda: _mk_kv(cfg, batch, length), n_main)
        return out

    return jax.eval_shape(build)


def _mk_kv(cfg: ModelConfig, batch: int, length: int):
    mk = mla.init_mla_cache if cfg.mla else attn.init_kv_cache
    return mk(cfg, batch, length)


def _stack(mk, n):
    one = mk()
    return jax.tree.map(
        lambda t: jnp.broadcast_to(t[None], (n,) + t.shape), one)


def decode_inputs_struct(cfg: ModelConfig, shape: ShapeSpec):
    """(caches, tokens, pos) structs for serve_step."""
    B, S = shape.global_batch, shape.seq_len
    caches = cache_struct(cfg, B, S)
    tok_shape = (B, 1, cfg.n_codebooks) if cfg.family == "audio" else (B, 1)
    return caches, _sds(tok_shape, I32), _sds((), I32)


def pick_accum(cfg: ModelConfig, shape: ShapeSpec, mesh,
               batch_axes) -> int:
    """Grad-accumulation factor: bound per-device f32 logits + stored
    residuals to ~1.5 GB (the perf-note-B1 memory budget, docs/ARCHITECTURE.md)."""
    if shape.kind != "train":
        return 1
    nb = 1
    for a in batch_axes:
        nb *= mesh.shape[a]
    b_loc = shape.global_batch // nb
    mshard = mesh.shape["model"] if "model" in mesh.shape else 1
    v_loc = cfg.vocab // mshard if cfg.vocab % mshard == 0 else cfg.vocab
    budget = 1.5e9
    accum = 1
    while accum < b_loc:
        logit_bytes = (b_loc // accum) * shape.seq_len * v_loc * 4
        if logit_bytes <= budget:
            break
        accum *= 2
    # keep microbatch divisible by the batch shards
    while (shape.global_batch // accum) % nb:
        accum //= 2
    return max(accum, 1)
