"""Dry-run of the paper's own workload at cluster scale: distributed
mixed-precision Cholesky of n=65536 (the paper's headline size) sharded
over 256 chips, with both collective schedules (perf notes C1-C3, docs/ARCHITECTURE.md).

Usage:
  PYTHONPATH=src python -m repro.launch.solver_dryrun \
      [--n 65536] [--shards 256] [--schedule bcast|gather] \
      [--levels f16,f16,f32] [--out experiments/dryrun]
"""
from __future__ import annotations

import os  # noqa: E402
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import functools
import json

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import PrecisionConfig
from repro.core.distributed import dist_cholesky
from repro.launch import hloparse
from repro.launch.mesh import make_mesh


def run(n=65536, shards=256, schedule="bcast", levels=("bf16", "f32"),
        leaf=256, out_dir="experiments/dryrun", compress_comm=False):
    mesh = make_mesh((shards,), ("model",))
    cfg = PrecisionConfig(levels=tuple(levels), leaf=leaf)
    a_struct = jax.ShapeDtypeStruct((n, n), jnp.float32)
    sh = NamedSharding(mesh, P("model", None))
    fn = functools.partial(dist_cholesky, mesh=mesh, cfg=cfg,
                           broadcast_diag_only=(schedule == "bcast"),
                           compress_comm=compress_comm)
    with mesh:
        jf = jax.jit(fn, in_shardings=(sh,), out_shardings=sh,
                     donate_argnums=(0,))
        lowered = jf.lower(a_struct)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cen = hloparse.census(compiled.as_text())
    rec = {
        "arch": f"dist-cholesky-n{n}", "shape": f"x{shards}chips",
        "multi_pod": False, "n_devices": shards,
        "n_params": n * n, "kfac": True,  # tag: paper-technique cell
        "schedule": schedule + ("+qcomm" if compress_comm else ""),
        "levels": list(levels),
        "per_device_bytes": (mem.argument_size_in_bytes
                             + mem.output_size_in_bytes
                             + mem.temp_size_in_bytes
                             - mem.alias_size_in_bytes),
        "memory": {"temp_bytes": mem.temp_size_in_bytes,
                   "argument_bytes": mem.argument_size_in_bytes},
        "census": {"flops": cen["flops"], "hbm_bytes": cen["hbm_bytes"],
                   "loops": cen["loops"]},
        "collectives": cen["collectives"],
    }
    os.makedirs(out_dir, exist_ok=True)
    name = (f"solver__n{n}_p{shards}_{schedule}"
            f"{'-qcomm' if compress_comm else ''}_{'-'.join(levels)}")
    with open(os.path.join(out_dir, name + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    coll = sum(v["bytes"] for v in cen["collectives"].values())
    print(f"{name}: flops/dev={cen['flops']:.3e} "
          f"coll/dev={coll:.3e}B "
          f"mem/dev={rec['per_device_bytes'] / 2**30:.2f}GiB")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=65536)
    ap.add_argument("--shards", type=int, default=256)
    ap.add_argument("--schedule", default="bcast",
                    choices=("bcast", "gather"))
    ap.add_argument("--levels", default="bf16,f32")
    ap.add_argument("--leaf", type=int, default=256)
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--compress-comm", action="store_true")
    a = ap.parse_args()
    run(a.n, a.shards, a.schedule, tuple(a.levels.split(",")), a.leaf,
        a.out, a.compress_comm)


if __name__ == "__main__":
    main()
