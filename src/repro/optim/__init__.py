"""Optimizers: AdamW + TreeNewton (K-FAC-style, tree-Cholesky solves)."""
from repro.optim import adamw, kfac  # noqa: F401
from repro.optim.adamw import AdamWConfig  # noqa: F401
from repro.optim.kfac import TreeNewtonConfig  # noqa: F401
