"""Tree-Newton: Kronecker-factored preconditioning whose SPD solves run
through the paper's mixed-precision tree-Cholesky (docs/ARCHITECTURE.md, "Model and training integrations").

This is the production integration of the paper's solver into the LM
trainer: per-matrix second-moment factors

    A = EMA[ G G^T ] + damping * tr(A)/n * I        (block-diagonal)

are factorized every ``factor_every`` steps under the configured
precision ladder by the engine ``cfg.precision.engine`` selects —
``blocked_potrf`` on the default flat schedule, ``tree_potrf`` as the
reference path, or the tuning database's pick under ``"auto"`` — and
every step the gradient direction is whitened by the cached factor via
two ``tree_trsm_left`` solves (L L^T X = G). The magnitude is *grafted* from AdamW (distributed-Shampoo
practice), so the solver provides the direction and Adam provides the
scale — a one-sided, Cholesky-based relative of Shampoo/K-FAC that is
stable at power -1.

Large matrices are partitioned into ``block`` x ``block`` diagonal blocks
(Shampoo blocking), which is also exactly the regime the paper's
recursive solver targets: many independent SPD factorizations per step,
batched with vmap over (layers x blocks).

Stats/factors are maintained only for leaves selected by
``eligible_paths`` (attention + MLP projection matrices); everything else
falls back to plain AdamW.
"""
from __future__ import annotations

import dataclasses
import re

import jax
import jax.numpy as jnp

from repro.core.blocked import blocked_potrf
from repro.core.precision import PrecisionConfig
from repro.core.refine import refine_steps, scaled_solve
from repro.core.tree import tree_potrf, tree_trsm_left
from repro.optim import adamw

ELIGIBLE = re.compile(
    r"(mlp/(w_in|w_gate|w_out)|attn/(wq|wk|wv|wo)|ck|cv|w_out|w_in)$")


@dataclasses.dataclass(frozen=True)
class TreeNewtonConfig:
    adam: adamw.AdamWConfig = dataclasses.field(
        default_factory=adamw.AdamWConfig)
    precision: PrecisionConfig = dataclasses.field(
        default_factory=lambda: PrecisionConfig(levels=("bf16", "f32"),
                                                leaf=128))
    block: int = 512            # Shampoo block size (multiple of leaf)
    stats_every: int = 1
    factor_every: int = 10
    damping: float = 1e-3
    ema: float = 0.95
    max_side: int = 32768       # skip matrices with larger fan-in
    refine_sweeps: int = 0      # IR sweeps per whiten, reusing the cached
                                # factor against the CURRENT damped stats —
                                # tightens the solve between refactors


def _path_str(path):
    parts = []
    for p in path:
        parts.append(str(getattr(p, "key", getattr(p, "idx", p))))
    return "/".join(parts)


def _eligible(path, leaf, cfg: TreeNewtonConfig):
    if leaf.ndim not in (2, 3):
        return False
    din = leaf.shape[-2]
    if din % cfg.block != 0 or din > cfg.max_side:
        return False
    return bool(ELIGIBLE.search(_path_str(path)))


def _to_blocks(g, block):
    """[..., din, dout] -> [..., nb, block, dout]"""
    *lead, din, dout = g.shape
    return g.reshape(*lead, din // block, block, dout)


def init(params, cfg: TreeNewtonConfig):
    adam_state = adamw.init(params, cfg.adam)

    def stat_init(path, leaf):
        if not _eligible(path, leaf, cfg):
            return None
        *lead, din, dout = leaf.shape
        nb = din // cfg.block
        eye = jnp.eye(cfg.block, dtype=jnp.float32)
        shape = (*lead, nb, cfg.block, cfg.block)
        return jnp.broadcast_to(eye, shape)

    stats = jax.tree_util.tree_map_with_path(stat_init, params)
    factors = jax.tree.map(lambda s: s, stats)   # chol(I) = I
    return {"adam": adam_state, "stats": stats, "factors": factors,
            "count": jnp.zeros((), jnp.int32)}


def _update_stats(g, a, cfg: TreeNewtonConfig):
    gb = _to_blocks(g.astype(jnp.float32), cfg.block)
    gg = jnp.einsum("...io,...jo->...ij", gb, gb) / gb.shape[-1]
    return cfg.ema * a + (1 - cfg.ema) * gg


def _damped(a, cfg: TreeNewtonConfig):
    n = a.shape[-1]
    tr = jnp.trace(a, axis1=-2, axis2=-1)[..., None, None] / n
    return a + (cfg.damping * tr + 1e-12) * jnp.eye(n, dtype=a.dtype)


def _refactor(a, cfg: TreeNewtonConfig):
    """vmap the engine POTRF over (layers x blocks) of damped stats.

    ``engine="auto"`` resolves against the tuning database at the block
    size. Blocks that are not a multiple of the leaf (small ``block``
    configs) stay on the tree engine, whose base case handles any
    ``n <= leaf`` without padding.
    """
    n = a.shape[-1]
    pcfg = cfg.precision
    if pcfg.engine == "auto":
        from repro import tune  # local: avoid import cycle at module load
        pcfg = tune.resolve_cfg(pcfg, n)
    potrf = (blocked_potrf if pcfg.engine == "blocked"
             and n % pcfg.leaf == 0 else tree_potrf)
    flat = _damped(a, cfg).reshape(-1, n, n)
    chol = jax.vmap(lambda m: potrf(m, pcfg))(flat)
    return chol.reshape(a.shape)


def _whiten(g, l, a, cfg: TreeNewtonConfig):
    """Solve (L L^T) X = G per block via two tree solves; keep grafted
    AdamW magnitude (per-matrix norm).

    With ``refine_sweeps > 0``, each base solve is followed by unrolled
    IR sweeps against the CURRENT damped stats ``a`` — the cached factor
    (possibly ``factor_every`` steps stale) is reused as the corrector,
    so curvature drift between refactors is absorbed at O(n^2) cost
    instead of an O(n^3) refactorization.
    """
    gb = _to_blocks(g.astype(jnp.float32), cfg.block)
    shape = gb.shape
    n, dout = shape[-2], shape[-1]
    gf = gb.reshape(-1, n, dout)
    lf = l.reshape(-1, n, n)
    af = _damped(a, cfg).astype(jnp.float32).reshape(-1, n, n)

    def solve(li, ai, gi):
        def base(r):
            y = tree_trsm_left(r, li, cfg.precision, trans=False)
            return tree_trsm_left(y, li, cfg.precision, trans=True)

        x = base(gi)
        if cfg.refine_sweeps > 0:
            x = refine_steps(lambda v: ai @ v, scaled_solve(base), gi, x,
                             cfg.refine_sweeps)
        return x

    x = jax.vmap(solve)(lf, af, gf).reshape(shape)
    x = x.reshape(g.shape)
    # graft: rescale to the raw gradient's norm per matrix
    axes = tuple(range(g.ndim - 2, g.ndim))
    gn = jnp.sqrt(jnp.sum(jnp.square(g.astype(jnp.float32)), axis=axes,
                          keepdims=True))
    xn = jnp.sqrt(jnp.sum(jnp.square(x), axis=axes, keepdims=True))
    return x * (gn / jnp.maximum(xn, 1e-12))


def apply(grads, state, params, cfg: TreeNewtonConfig):
    """Precondition eligible gradients, then AdamW on the result."""
    count = state["count"] + 1

    def maybe_stats(path, a, g):
        if a is None:
            return None
        return jax.lax.cond(count % cfg.stats_every == 0,
                            lambda: _update_stats(g, a, cfg), lambda: a)

    stats = jax.tree_util.tree_map_with_path(
        maybe_stats, state["stats"], grads, is_leaf=lambda x: x is None)

    def maybe_factor(a, l):
        if a is None:
            return None
        return jax.lax.cond(count % cfg.factor_every == 0,
                            lambda: _refactor(a, cfg), lambda: l)

    factors = jax.tree.map(maybe_factor, stats, state["factors"],
                           is_leaf=lambda x: x is None)

    def precond(l, a, g):
        if l is None:
            return g
        return _whiten(g, l, a, cfg)

    pgrads = jax.tree.map(precond, factors, stats, grads,
                          is_leaf=lambda x: x is None)
    new_params, adam_state, metrics = adamw.apply(
        pgrads, state["adam"], params, cfg.adam)
    new_state = {"adam": adam_state, "stats": stats, "factors": factors,
                 "count": count}
    return new_params, new_state, metrics
