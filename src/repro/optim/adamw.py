"""AdamW with configurable state dtype + cosine schedule + global clip.

State dtype matters at 671B scale: bf16 m/v keep the optimizer inside
16 GB/chip HBM (see docs/ARCHITECTURE.md, "Performance notes" B1); f32 master moments are
the default for <100B models.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

_DT = {"f32": jnp.float32, "bf16": jnp.bfloat16}


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    state_dtype: str = "f32"


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup) /
                    jnp.maximum(cfg.total_steps - cfg.warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init(params, cfg: AdamWConfig):
    dt = _DT[cfg.state_dtype]
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), \
        gnorm


def apply(grads, state, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics). Grads may be any float
    dtype; math in f32; params updated in their own dtype."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    count = state["count"] + 1
    lr = schedule(cfg, count)
    c = count.astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** c
    bc2 = 1 - cfg.b2 ** c
    dt = _DT[cfg.state_dtype]

    def upd(p, g, m, v):
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        step = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * step
        return newp.astype(p.dtype), m32.astype(dt), v32.astype(dt)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}, \
        {"lr": lr, "grad_norm": gnorm}
